//! Properties of the closed-loop streaming engine:
//!
//! 1. **Low-load equivalence** — with one client, batch size 1 and a
//!    frame period longer than the pipeline latency, the closed-loop
//!    engine reproduces the legacy open-loop per-frame latencies exactly
//!    for UDP (any loss) and lossless TCP (the retained
//!    `run_scenario_open_loop` / `simulate_latency_open_loop` reference),
//!    so Fig. 3/4-style results at low load are unchanged. Under *lossy*
//!    TCP the transfers themselves are still identical, but the closed
//!    loop additionally counts the time a result waits for the channel to
//!    drain the upstream ACK tail — time the open-loop accounting
//!    silently dropped — so its per-frame latency is `>=` the legacy
//!    value, with most frames exactly equal.
//! 2. **Divergence under overload** — past the bottleneck the closed-loop
//!    latency grows with queue depth while the open-loop model stays
//!    flat: the timing bug this engine fixes is observable.
//! 3. **Monotonicity** — per-frame latency is non-decreasing in offered
//!    load at fixed capacity.
//! 4. **Conservation** — frames in == frames out across random
//!    configurations (no request lost in queues or batches).
//! 5. **Saturation** — throughput plateaus at the bottleneck while
//!    mean/p99 latency grow.

use std::path::Path;

use sei::coordinator::batcher::BatchPolicy;
use sei::coordinator::scenario::{
    run_scenario_open_loop, simulate_latency_open_loop,
};
use sei::coordinator::{
    self, run_stream, ModelScale, QosRequirements, ScenarioConfig,
    ScenarioKind, StreamConfig,
};
use sei::model::DeviceProfile;
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::runtime::{load_backend, InferenceBackend};

fn engine() -> Box<dyn InferenceBackend> {
    load_backend(Path::new("artifacts")).expect("backend")
}

fn cfg(
    kind: ScenarioKind,
    proto: Protocol,
    loss: f64,
    scale: ModelScale,
    period_ns: u64,
) -> ScenarioConfig {
    ScenarioConfig::two_tier(
        kind,
        NetworkConfig::gigabit(proto, loss, 42),
        DeviceProfile::edge_gpu(),
        DeviceProfile::server_gpu(),
        scale,
        period_ns,
    )
}

#[test]
fn closed_loop_matches_open_loop_at_low_load() {
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let qos = QosRequirements::ice_lab();
    let split = *engine.manifest().available_splits().last().unwrap();
    for kind in [ScenarioKind::Lc, ScenarioKind::Rc,
                 ScenarioKind::Sc { split }] {
        for proto in [Protocol::Tcp, Protocol::Udp] {
            for loss in [0.0, 0.05] {
                let c = cfg(kind.clone(), proto, loss, ModelScale::Slim,
                            50_000_000);
                let closed = coordinator::run_scenario(
                    &*engine, &c, &test, 32, &qos,
                )
                .unwrap();
                let open = run_scenario_open_loop(
                    &*engine, &c, &test, 32, &qos,
                )
                .unwrap();
                assert_eq!(closed.frames, open.frames);
                // The transfers themselves are identical in every case:
                // accuracy, corruption, wire bytes and retransmits match
                // frame by frame.
                let mut equal_latency = 0usize;
                for (i, (a, b)) in
                    closed.records.iter().zip(&open.records).enumerate()
                {
                    assert_eq!(a.correct, b.correct);
                    assert_eq!(a.wire_bytes, b.wire_bytes);
                    assert_eq!(a.retransmits, b.retransmits);
                    assert_eq!(a.corrupted, b.corrupted);
                    if proto == Protocol::Udp || loss == 0.0 {
                        // No ACK tail (UDP) or a fully predictable one
                        // (lossless TCP): latencies must be *identical*.
                        assert_eq!(
                            a.latency_ns, b.latency_ns,
                            "{kind} {proto} loss {loss} frame {i}"
                        );
                    } else {
                        // Lossy TCP: the closed loop also counts the wait
                        // for the channel to drain the upstream ACK tail,
                        // which the open-loop accounting dropped.
                        assert!(
                            a.latency_ns >= b.latency_ns,
                            "{kind} {proto} loss {loss} frame {i}: closed \
                             {} < open {}",
                            a.latency_ns, b.latency_ns
                        );
                    }
                    if a.latency_ns == b.latency_ns {
                        equal_latency += 1;
                    }
                }
                assert!(
                    equal_latency * 2 >= closed.frames,
                    "{kind} {proto} loss {loss}: only {equal_latency}/{} \
                     frames latency-identical",
                    closed.frames
                );
                assert_eq!(closed.accuracy, open.accuracy);
                assert_eq!(closed.total_retransmits, open.total_retransmits);
            }
        }
    }
}

#[test]
fn latency_only_matches_open_loop_at_low_load() {
    let engine = engine();
    let split = *engine.manifest().available_splits().last().unwrap();
    for (kind, proto, loss) in [
        (ScenarioKind::Lc, Protocol::Tcp, 0.0),
        (ScenarioKind::Sc { split }, Protocol::Tcp, 0.0),
        (ScenarioKind::Sc { split }, Protocol::Udp, 0.10),
    ] {
        let c = cfg(kind.clone(), proto, loss, ModelScale::Slim, 50_000_000);
        let closed =
            coordinator::simulate_latency(&*engine, &c, 48).unwrap();
        let open = simulate_latency_open_loop(&*engine, &c, 48).unwrap();
        assert_eq!(closed, open, "{kind} {proto} loss {loss}");
    }
    // Lossy TCP: identical transfers, but the closed loop also bills the
    // ACK-tail wait the open loop dropped — per-frame >=, mostly equal.
    let c = cfg(ScenarioKind::Sc { split }, Protocol::Tcp, 0.03,
                ModelScale::Slim, 50_000_000);
    let closed = coordinator::simulate_latency(&*engine, &c, 48).unwrap();
    let open = simulate_latency_open_loop(&*engine, &c, 48).unwrap();
    let mut equal = 0usize;
    for (i, (a, b)) in closed.iter().zip(&open).enumerate() {
        assert!(a >= b, "frame {i}: closed {a} < open {b}");
        if a == b {
            equal += 1;
        }
    }
    assert!(equal * 2 >= closed.len(), "only {equal}/48 frames identical");
    // The open-loop latency-only path charged RC frames a phantom edge
    // pass (compute_ns(0) = the edge overhead); the unified closed-loop
    // path does not. The difference is exactly that constant.
    let c = cfg(ScenarioKind::Rc, Protocol::Udp, 0.0, ModelScale::Slim,
                50_000_000);
    let closed = coordinator::simulate_latency(&*engine, &c, 16).unwrap();
    let open = simulate_latency_open_loop(&*engine, &c, 16).unwrap();
    let overhead = DeviceProfile::edge_gpu().overhead_ns;
    for (a, b) in closed.iter().zip(&open) {
        assert_eq!(a + overhead, *b);
    }
}

#[test]
fn overload_diverges_from_open_loop() {
    let engine = engine();
    // Paper-scale RC input (~602 kB -> ~4.9 ms on the uplink) offered at
    // 1000 FPS: far past the channel's capacity.
    let c = cfg(ScenarioKind::Rc, Protocol::Udp, 0.0,
                ModelScale::Full, 1_000_000);
    let closed = coordinator::simulate_latency(&*engine, &c, 64).unwrap();
    let open = simulate_latency_open_loop(&*engine, &c, 64).unwrap();
    let mean = |v: &[u64]| {
        v.iter().map(|&x| x as f64).sum::<f64>() / v.len() as f64
    };
    assert!(
        mean(&closed) > 3.0 * mean(&open),
        "queueing must show up in closed-loop latency: closed {} vs open {}",
        mean(&closed),
        mean(&open)
    );
    // The queue (and with it the latency) builds monotonically.
    assert!(closed.last().unwrap() > closed.first().unwrap());
    // The open-loop model is the bug: its latency stays flat regardless.
    let spread = (*open.iter().max().unwrap() - *open.iter().min().unwrap())
        as f64;
    assert!(spread < 0.1 * mean(&open), "open loop stays flat: {open:?}");
}

#[test]
fn per_frame_latency_monotone_in_offered_load() {
    let engine = engine();
    let qos = QosRequirements::none();
    let ladder = [50.0f64, 100.0, 200.0, 400.0];
    let mut prev: Option<Vec<u64>> = None;
    let mut prev_mean = 0.0;
    let mut prev_p99 = 0u64;
    for &fps in &ladder {
        let sc = StreamConfig {
            scenario: cfg(ScenarioKind::Rc, Protocol::Udp, 0.0,
                          ModelScale::Full, (1e9 / fps) as u64),
            clients: 1,
            frames_per_client: 48,
            batch: BatchPolicy::immediate(),
        };
        let r = run_stream(&*engine, &sc, None, &qos).unwrap();
        let lats: Vec<u64> =
            r.records.iter().map(|f| f.latency_ns).collect();
        if let Some(lo) = &prev {
            for (i, (&hi, &lo)) in lats.iter().zip(lo).enumerate() {
                assert!(
                    hi >= lo,
                    "frame {i} latency decreased under higher load: \
                     {hi} < {lo} at {fps} FPS"
                );
            }
            assert!(r.mean_latency_ns >= prev_mean);
            assert!(r.p99_latency_ns >= prev_p99);
        }
        prev_mean = r.mean_latency_ns;
        prev_p99 = r.p99_latency_ns;
        prev = Some(lats);
    }
}

#[test]
fn prop_no_frame_lost_across_queues_and_batches() {
    use sei::util::propcheck::{check, Config};
    let engine = engine();
    let split = *engine.manifest().available_splits().last().unwrap();
    check("stream_conservation", Config::default(), |c| {
        let kind = c
            .choice(&[
                ScenarioKind::Lc,
                ScenarioKind::Rc,
                ScenarioKind::Sc { split },
            ])
            .clone();
        let proto =
            if c.bool() { Protocol::Tcp } else { Protocol::Udp };
        let loss = c.f64(0.0, 0.2);
        let clients = c.sized_range(1, 4) as usize;
        let frames = c.sized_range(1, 16) as usize;
        let period = if c.bool() {
            0
        } else {
            c.rng.range_u64(10_000, 5_000_000)
        };
        let max_batch = c.sized_range(1, 8) as usize;
        let wait = c.rng.range_u64(1, 2_000_000);
        let sc = StreamConfig {
            scenario: ScenarioConfig::two_tier(
                kind.clone(),
                NetworkConfig::gigabit(proto, loss, c.rng.next_u64()),
                DeviceProfile::edge_gpu(),
                DeviceProfile::server_gpu(),
                ModelScale::Slim,
                period,
            ),
            clients,
            frames_per_client: frames,
            batch: BatchPolicy::new(max_batch, wait),
        };
        let r = run_stream(&*engine, &sc, None, &QosRequirements::none())
            .map_err(|e| e.to_string())?;
        if r.frames != clients * frames {
            return Err(format!(
                "lost frames: {} of {}",
                r.frames,
                clients * frames
            ));
        }
        for f in &r.records {
            if f.completed_ns < f.emitted_ns {
                return Err("completed before emitted".into());
            }
            if f.latency_ns != f.completed_ns - f.emitted_ns {
                return Err("latency bookkeeping broken".into());
            }
        }
        let expects_uplink = kind != ScenarioKind::Lc;
        let expected =
            if expects_uplink { (clients * frames) as u64 } else { 0 };
        if r.stats.batched_requests != expected {
            return Err(format!(
                "batcher saw {} requests, expected {expected}",
                r.stats.batched_requests
            ));
        }
        Ok(())
    });
}

#[test]
fn throughput_plateaus_past_bottleneck() {
    let engine = engine();
    let qos = QosRequirements::ice_lab();
    let run = |fps: f64| {
        let sc = StreamConfig {
            scenario: cfg(ScenarioKind::Rc, Protocol::Udp, 0.0,
                          ModelScale::Full, (1e9 / fps) as u64),
            clients: 1,
            frames_per_client: 64,
            batch: BatchPolicy::immediate(),
        };
        run_stream(&*engine, &sc, None, &qos).unwrap()
    };
    let lo = run(50.0);
    let mid = run(400.0);
    let hi = run(800.0);
    // Below capacity the system keeps up with the offered rate…
    assert!(
        (lo.stats.throughput_fps - 50.0).abs() < 5.0,
        "under low load throughput tracks offered: {}",
        lo.stats.throughput_fps
    );
    assert!(lo.deadline_hit_rate.unwrap() > 0.99);
    // …past the bottleneck, throughput plateaus…
    let rel = (hi.stats.throughput_fps - mid.stats.throughput_fps).abs()
        / mid.stats.throughput_fps;
    assert!(
        rel < 0.05,
        "throughput must plateau: {} vs {}",
        mid.stats.throughput_fps,
        hi.stats.throughput_fps
    );
    assert!(hi.stats.throughput_fps < 0.5 * 800.0);
    // …and latency + queue depth grow instead.
    assert!(hi.mean_latency_ns > 3.0 * lo.mean_latency_ns);
    assert!(hi.p99_latency_ns > 3 * lo.p99_latency_ns);
    assert!(hi.stats.mean_queue_depth > lo.stats.mean_queue_depth);
    assert!(hi.deadline_hit_rate.unwrap() < lo.deadline_hit_rate.unwrap());
    assert_eq!(hi.qos_satisfied, Some(false));
}
