//! Properties of the heterogeneous multi-tenant streaming engine:
//!
//! 1. **Determinism** — a mixed per-client population (architectures ×
//!    placements × rates × weights) produces byte-identical record
//!    streams and event counts on repeated runs.
//! 2. **Admission isolation** — an admitted stream's records are byte
//!    identical whether or not other streams were rejected: rejection
//!    means the stream never emits, so survivors cannot observe it.
//! 3. **DRR starvation bound** — under a 100:1 offered-rate skew on a
//!    shared uplink, deficit round robin keeps the light tenant's
//!    latency bounded near its unloaded cost while FIFO lets the hog's
//!    backlog starve it.
//! 4. **Conservation** — every admitted client's frames all complete.

use std::path::Path;

use sei::coordinator::batcher::BatchPolicy;
use sei::coordinator::{
    run_hetero_stream, ClientSpec, Fairness, ModelScale, MultiStreamConfig,
    QosRequirements, ScenarioKind,
};
use sei::model::{Arch, DeviceProfile};
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::netsim::QueueKind;
use sei::runtime::{load_backend_for, InferenceBackend};

fn engines() -> Vec<(Arch, Box<dyn InferenceBackend>)> {
    [Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2]
        .into_iter()
        .map(|a| {
            (a, load_backend_for(Path::new("artifacts"), a).expect("backend"))
        })
        .collect()
}

fn engine_refs(
    owned: &[(Arch, Box<dyn InferenceBackend>)],
) -> Vec<(Arch, &dyn InferenceBackend)> {
    owned.iter().map(|(a, b)| (*a, &**b)).collect()
}

fn base_cfg(clients: Vec<ClientSpec>) -> MultiStreamConfig {
    MultiStreamConfig {
        clients,
        hop_nets: vec![NetworkConfig::gigabit(Protocol::Udp, 0.0, 9)],
        tiers: vec![DeviceProfile::edge_gpu(), DeviceProfile::server_gpu()],
        batch: BatchPolicy::immediate(),
        fairness: Fairness::Drr,
        admission: true,
        queue: QueueKind::Calendar,
    }
}

fn mixed_population() -> Vec<ClientSpec> {
    let mut a = ClientSpec::new(ScenarioKind::Rc);
    a.frame_period_ns = 2_000_000;
    a.frames = 8;
    let mut b = ClientSpec::new(ScenarioKind::Sc { split: 5 });
    b.arch = Arch::ResNet18;
    b.frame_period_ns = 3_000_000;
    b.frames = 6;
    b.weight = 4;
    let mut c = ClientSpec::new(ScenarioKind::Lc);
    c.arch = Arch::MobileNetV2;
    c.frames = 5; // closed-loop (period 0)
    let mut d = ClientSpec::new(ScenarioKind::Rc);
    d.arch = Arch::MobileNetV2;
    d.scale = ModelScale::Full;
    d.frame_period_ns = 5_000_000;
    d.frames = 4;
    vec![a, b, c, d]
}

#[test]
fn mixed_population_is_deterministic() {
    let owned = engines();
    let refs = engine_refs(&owned);
    let cfg = base_cfg(mixed_population());
    let qos = QosRequirements::none();
    let r1 = run_hetero_stream(&refs, &cfg, None, &qos).unwrap();
    let r2 = run_hetero_stream(&refs, &cfg, None, &qos).unwrap();
    assert_eq!(r1.aggregate.records, r2.aggregate.records);
    assert_eq!(
        r1.aggregate.stats.events_processed,
        r2.aggregate.stats.events_processed
    );
    assert_eq!(r1.admitted(), 4);
    // Conservation: every admitted client's frames all complete, grouped
    // per client in frame order.
    assert_eq!(r1.aggregate.frames, 8 + 6 + 5 + 4);
    for o in &r1.outcomes {
        assert!(o.admitted, "client {} unexpectedly rejected", o.client);
        assert_eq!(o.frames, cfg.clients[o.client].frames);
    }
    let per_client: Vec<usize> = (0..4)
        .map(|c| {
            r1.aggregate
                .records
                .iter()
                .filter(|r| r.client == c)
                .count()
        })
        .collect();
    assert_eq!(per_client, vec![8, 6, 5, 4]);
}

#[test]
fn admitted_streams_are_isolated_from_rejected_ones() {
    let owned = engines();
    let refs = engine_refs(&owned);
    // The light, admissible clients come FIRST so greedy admission keeps
    // them; the hog's 1 ns period then provably oversubscribes the lane.
    let mut light = ClientSpec::new(ScenarioKind::Rc);
    light.frame_period_ns = 5_000_000;
    light.frames = 6;
    let mut light2 = ClientSpec::new(ScenarioKind::Sc { split: 5 });
    light2.arch = Arch::ResNet18;
    light2.frame_period_ns = 4_000_000;
    light2.frames = 5;
    let mut hog = ClientSpec::new(ScenarioKind::Rc);
    hog.frame_period_ns = 1;
    hog.frames = 64;
    let qos = QosRequirements::none();

    let with_hog = base_cfg(vec![
        light.clone(),
        light2.clone(),
        hog,
    ]);
    let solo = base_cfg(vec![light, light2]);
    let r_with = run_hetero_stream(&refs, &with_hog, None, &qos).unwrap();
    let r_solo = run_hetero_stream(&refs, &solo, None, &qos).unwrap();

    assert_eq!(r_with.admitted(), 2);
    let rej = &r_with.outcomes[2];
    assert!(!rej.admitted);
    let reason = rej.reject_reason.as_deref().unwrap();
    assert!(reason.contains("admission"), "{reason}");
    assert_eq!(rej.frames, 0);
    // Byte-identical survivor streams: the rejected hog never emitted, so
    // the admitted clients' records cannot depend on its presence.
    assert_eq!(r_with.aggregate.records, r_solo.aggregate.records);
}

#[test]
fn drr_bounds_the_light_tenant_under_100_to_1_skew() {
    let owned = engines();
    let refs = engine_refs(&owned);
    let qos = QosRequirements::none();
    // Light tenant first: 10 frames at 2 kHz. The hog offers 100x that
    // rate — far past the shared uplink's capacity, so its backlog grows
    // for the whole run. Admission is off: starving the queue is the
    // point of this test.
    let mut light = ClientSpec::new(ScenarioKind::Rc);
    light.frame_period_ns = 500_000;
    light.frames = 10;
    let mut hog = ClientSpec::new(ScenarioKind::Rc);
    hog.frame_period_ns = 5_000;
    hog.frames = 400;

    let mean_light = |fairness: Fairness| -> f64 {
        let mut cfg = base_cfg(vec![light.clone(), hog.clone()]);
        cfg.fairness = fairness;
        cfg.admission = false;
        let r = run_hetero_stream(&refs, &cfg, None, &qos).unwrap();
        assert_eq!(r.outcomes[0].frames, 10);
        r.outcomes[0].mean_latency_ns
    };
    let fifo = mean_light(Fairness::Fifo);
    let drr = mean_light(Fairness::Drr);
    // Under FIFO every light frame waits behind the hog's ever-growing
    // backlog; DRR serves the light tenant once per round, so its wait
    // behind the hog is bounded by ~one hog item per own item.
    assert!(
        drr * 3.0 < fifo,
        "DRR must shield the light tenant: drr {:.3} ms vs fifo {:.3} ms",
        drr / 1e6,
        fifo / 1e6
    );
}
