//! Property-based tests of the netsim invariants (util::propcheck).

use sei::netsim::event::EventQueue;
use sei::netsim::link::{Link, LinkConfig};
use sei::netsim::packet::{segment, TCP_MSS, UDP_MAX_PAYLOAD};
use sei::netsim::tcp::{self, TcpConfig, TcpState};
use sei::netsim::udp::{self, UdpConfig};
use sei::util::propcheck::{check, check_seeded, Config};
use sei::util::rng::Rng;

fn make_links(loss: f64, latency_ns: u64, rate: f64, seed: u64)
    -> (Link, Link)
{
    let cfg = LinkConfig::basic(latency_ns, rate, loss);
    let mut rng = Rng::new(seed);
    (Link::new(cfg.clone(), rng.fork()), Link::new(cfg, rng.fork()))
}

#[test]
fn prop_event_queue_total_order() {
    check("event_total_order", Config::default(), |c| {
        let n = c.sized_range(1, 200) as usize;
        let mut q = EventQueue::new();
        for i in 0..n {
            q.schedule(c.rng.below(10_000), i);
        }
        let mut last = 0u64;
        let mut popped = 0;
        while let Some((t, _)) = q.pop() {
            if t < last {
                return Err(format!("time went backwards: {t} < {last}"));
            }
            last = t;
            popped += 1;
        }
        if popped != n {
            return Err(format!("popped {popped} of {n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_segmentation_partitions_message() {
    check("segmentation_partition", Config::default(), |c| {
        let len = c.sized_range(1, 5_000_000);
        let mss = *c.choice(&[64u32, 536, TCP_MSS, UDP_MAX_PAYLOAD]);
        let segs = segment(len, mss);
        let mut expect = 0u64;
        for (off, p) in &segs {
            if *off != expect {
                return Err(format!("gap at {off} (expected {expect})"));
            }
            if *p == 0 || *p > mss {
                return Err(format!("bad payload {p}"));
            }
            expect += *p as u64;
        }
        if expect != len {
            return Err(format!("covered {expect} of {len}"));
        }
        Ok(())
    });
}

#[test]
fn prop_link_conserves_packets() {
    check("link_conservation", Config::default(), |c| {
        let loss = c.f64(0.0, 0.9);
        let n = c.sized_range(1, 500);
        let mut link = Link::new(
            LinkConfig::basic(1000, 1e9, loss),
            Rng::new(c.rng.next_u64()),
        );
        let mut delivered = 0u64;
        for i in 0..n {
            if !link.send(i * 10, 100).dropped {
                delivered += 1;
            }
        }
        let s = link.stats;
        if s.packets_sent != n {
            return Err("sent count mismatch".into());
        }
        if delivered + s.packets_dropped != s.packets_sent {
            return Err(format!(
                "conservation violated: {delivered} + {} != {}",
                s.packets_dropped, s.packets_sent
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_link_fifo_and_monotone_arrivals() {
    check("link_fifo", Config::default(), |c| {
        let mut link = Link::new(
            {
                let mut lc = LinkConfig::basic(
                    c.rng.range_u64(0, 1_000_000), 1e8, 0.0);
                lc.interface_bps = 1e9;
                lc
            },
            Rng::new(1),
        );
        let mut last_arrival = 0;
        let mut t = 0u64;
        for _ in 0..c.sized_range(2, 100) {
            t += c.rng.below(10_000);
            let out = link.send(t, 100 + c.rng.below(1400) as u32);
            if out.arrival < last_arrival {
                return Err("arrivals reordered on FIFO link".into());
            }
            if out.tx_done < t {
                return Err("tx finished before send".into());
            }
            last_arrival = out.arrival;
        }
        Ok(())
    });
}

#[test]
fn prop_tcp_always_delivers_everything() {
    // The core reliability invariant: for any loss < 1, every byte is
    // delivered and acknowledged in bounded (simulated) time.
    check_seeded("tcp_reliability", Config { cases: 48, base_seed: 11 },
                 |seed, size| {
        let mut rng = Rng::new(seed);
        let len = 1 + (rng.below(400_000) as f64 * size) as u64;
        let loss = rng.range_f64(0.0, 0.35);
        let (mut d, mut a) = make_links(loss, 100_000, 1e9, seed ^ 0xabc);
        let cfg = TcpConfig::default();
        let mut st = TcpState::new(&cfg);
        let r = tcp::send_message(&cfg, &mut st, &mut d, &mut a, len, 0)?;
        if r.delivery_latency_ns == 0
            || r.ack_latency_ns < r.delivery_latency_ns
        {
            return Err(format!("inconsistent latencies: {r:?}"));
        }
        // Conservation: sent = segments + retransmits.
        if r.stats.data_packets_sent != r.stats.segments + r.stats.retransmits
        {
            return Err(format!("packet accounting broken: {:?}", r.stats));
        }
        Ok(())
    });
}

#[test]
fn prop_tcp_latency_monotone_in_loss_on_average() {
    // Averaged over seeds, mean delivery latency is non-decreasing in the
    // loss rate (TCP pays for loss with retransmissions — Fig. 3).
    let losses = [0.0, 0.05, 0.15];
    let mut means = Vec::new();
    for &loss in &losses {
        let mut total = 0.0;
        for seed in 0..30u64 {
            let (mut d, mut a) = make_links(loss, 100_000, 1e9, 500 + seed);
            let cfg = TcpConfig::default();
            let mut st = TcpState::new(&cfg);
            let r = tcp::send_message(&cfg, &mut st, &mut d, &mut a,
                                      150_000, 0)
                .unwrap();
            total += r.delivery_latency_ns as f64;
        }
        means.push(total / 30.0);
    }
    assert!(
        means[1] > means[0] && means[2] > means[1],
        "latency not monotone in loss: {means:?}"
    );
}

#[test]
fn prop_tcp_zero_loss_deterministic_and_no_retx() {
    check("tcp_lossless", Config { cases: 32, base_seed: 77 }, |c| {
        let len = c.sized_range(1, 2_000_000);
        let rate = *c.choice(&[1e8, 1e9]);
        let (mut d, mut a) = make_links(0.0, 50_000, rate, 3);
        let cfg = TcpConfig::default();
        let mut st = TcpState::new(&cfg);
        let r = tcp::send_message(&cfg, &mut st, &mut d, &mut a, len, 0)?;
        if r.stats.retransmits != 0 || r.stats.timeouts != 0 {
            return Err(format!("phantom loss: {:?}", r.stats));
        }
        // Latency is bounded below by serialization + propagation.
        let min = (len as f64 * 8.0 / rate * 1e9) as u64 + 50_000;
        if r.delivery_latency_ns < min {
            return Err(format!(
                "latency {} beats physics {min}",
                r.delivery_latency_ns
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_udp_delivered_subset_and_latency_loss_free() {
    check_seeded("udp_subset", Config { cases: 48, base_seed: 21 },
                 |seed, size| {
        let mut rng = Rng::new(seed);
        let len = 1 + (rng.below(2_000_000) as f64 * size) as u64;
        let loss = rng.range_f64(0.0, 0.8);
        let cfg = UdpConfig::default();

        let (mut link, _) = make_links(loss, 100_000, 1e9, seed);
        let r = udp::send_message(&cfg, &mut link, len, 0);

        // Lost ranges are disjoint, sorted, in-bounds.
        let mut prev_end = 0u64;
        for (off, l) in &r.lost_ranges {
            if *off < prev_end {
                return Err("lost ranges overlap or unsorted".into());
            }
            if off + *l as u64 > len {
                return Err("lost range out of message".into());
            }
            prev_end = off + *l as u64;
        }
        if r.lost_bytes() > len {
            return Err("lost more than sent".into());
        }

        // Latency must match the loss-free run exactly (UDP never waits).
        let (mut link0, _) = make_links(0.0, 100_000, 1e9, seed);
        let r0 = udp::send_message(&cfg, &mut link0, len, 0);
        if r.latency_ns != r0.latency_ns {
            return Err(format!(
                "UDP latency depends on loss: {} vs {}",
                r.latency_ns, r0.latency_ns
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_udp_loss_fraction_tracks_saboteur() {
    // With many packets, the delivered fraction concentrates around 1-p.
    for (seed, p) in [(1u64, 0.1f64), (2, 0.3), (3, 0.5)] {
        let (mut link, _) = make_links(p, 100_000, 1e9, seed);
        let len = 4_000_000u64;
        let r = udp::send_message(&UdpConfig::default(), &mut link, len, 0);
        let f = r.delivered_fraction(len);
        assert!(
            (f - (1.0 - p)).abs() < 0.04,
            "loss {p}: delivered fraction {f}"
        );
    }
}

#[test]
fn prop_channel_clock_monotone() {
    use sei::netsim::transfer::{Channel, NetworkConfig, Protocol};
    use sei::netsim::Dir;
    check_seeded("channel_clock", Config { cases: 24, base_seed: 5 },
                 |seed, size| {
        let mut rng = Rng::new(seed);
        let proto =
            if rng.chance(0.5) { Protocol::Tcp } else { Protocol::Udp };
        let mut ch = Channel::new(NetworkConfig::gigabit(
            proto,
            rng.range_f64(0.0, 0.2),
            seed,
        ));
        use sei::netsim::Dir::{Down, Up};
        let mut last = 0;
        for i in 0..(3 + (10.0 * size) as usize) {
            let dir: Dir = if i % 2 == 0 { Up } else { Down };
            let len = 1 + rng.below(100_000);
            ch.send(dir, len).map_err(|e| e.to_string())?;
            if ch.now() < last {
                return Err("channel clock went backwards".into());
            }
            last = ch.now();
        }
        Ok(())
    });
}
