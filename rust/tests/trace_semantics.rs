//! Time-varying channel acceptance tests.
//!
//! The correctness anchor of the trace refactor is **constant-trace
//! identity**: attaching a single-segment trace that restates a channel's
//! own parameters must reproduce the untraced stream byte-identically —
//! same per-frame records, accuracy, wire bytes and retransmits — for
//! every cut, transport and event-queue backend. Beyond the anchor: a
//! boundary-straddling transfer pays each segment's rate piecewise
//! (two-segment closed form at the channel layer), the committed trace
//! suite parses and runs, and on its degrading entry the adaptive
//! re-split controller strictly beats the best static cut chain while
//! remaining below the zero-switchover-cost oracle — deterministically
//! across queue backends.

use std::path::Path;

use sei::coordinator::batcher::BatchPolicy;
use sei::coordinator::{
    run_adaptive_comparison, run_stream_with_queue, AdaptiveConfig,
    ControllerConfig, ModelScale, PolicyOutcome, QosRequirements,
    ScenarioConfig, ScenarioKind, StreamConfig,
};
use sei::model::{split_points, Arch, DeviceProfile};
use sei::netsim::trace::{parse_trace_arg, LinkTrace};
use sei::netsim::transfer::{Channel, NetworkConfig, Protocol};
use sei::netsim::{Dir, QueueKind, SimTime};
use sei::runtime::{load_backend_for, InferenceBackend};

fn engine_for(arch: Arch) -> Box<dyn InferenceBackend> {
    // No artifacts directory in tests: loads the hermetic analytic backend.
    load_backend_for(Path::new("artifacts"), arch).expect("backend")
}

fn suite_arg(entry: &str) -> String {
    format!(
        "{}/../examples/specs/trace_suite.json#{entry}",
        env!("CARGO_MANIFEST_DIR")
    )
}

/// Attaching `LinkTrace::constant(net)` to every hop must not move a
/// single byte or nanosecond: the traced run's frame records equal the
/// untraced run's, across cuts × transports × queue backends.
#[test]
fn constant_trace_reproduces_untraced_stream_byte_identically() {
    let engine = engine_for(Arch::Vgg16);
    let ds = engine.dataset("test").unwrap();
    let qos = QosRequirements::with_fps(50.0).unwrap();
    let kinds = [
        (ScenarioKind::Rc, 2usize),
        (ScenarioKind::Sc { split: 5 }, 2),
        (ScenarioKind::Sc { split: 13 }, 2),
        (ScenarioKind::Mc { cuts: vec![5, 13] }, 3),
    ];
    for (kind, tiers) in &kinds {
        for proto in [Protocol::Tcp, Protocol::Udp] {
            let net = NetworkConfig::gigabit(proto, 0.02, 42);
            let chain: Vec<DeviceProfile> = match tiers {
                2 => vec![
                    DeviceProfile::edge_gpu(),
                    DeviceProfile::server_gpu(),
                ],
                _ => vec![
                    DeviceProfile::parse("sensor-npu").unwrap(),
                    DeviceProfile::edge_gpu(),
                    DeviceProfile::server_gpu(),
                ],
            };
            let scenario = ScenarioConfig {
                kind: kind.clone(),
                hop_nets: vec![net.clone()],
                tiers: chain,
                scale: ModelScale::Slim,
                frame_period_ns: 5_000_000,
            };
            let mut traced = scenario.clone();
            traced.hop_nets = vec![net
                .clone()
                .with_trace(LinkTrace::constant(&net))];
            for queue in [QueueKind::Calendar, QueueKind::LinearScan] {
                let run = |s: &ScenarioConfig| {
                    run_stream_with_queue(
                        &*engine,
                        &StreamConfig {
                            scenario: s.clone(),
                            clients: 1,
                            frames_per_client: 12,
                            batch: BatchPolicy::immediate(),
                        },
                        Some(&ds),
                        &qos,
                        queue,
                    )
                    .unwrap()
                };
                let a = run(&scenario);
                let b = run(&traced);
                assert_eq!(
                    a.records, b.records,
                    "records diverged: {kind} {proto} {queue:?}"
                );
                assert_eq!(a.accuracy, b.accuracy);
                assert_eq!(a.mean_latency_ns, b.mean_latency_ns);
                assert_eq!(a.total_retransmits, b.total_retransmits);
            }
        }
    }
}

/// A transfer that straddles a trace boundary pays each segment's rate
/// for the bits it moves inside that segment. One 1472 B UDP datagram
/// (1500 B on the wire = 12000 bits) on a 1 Gb/s -> 100 Mb/s schedule
/// switching at 6 µs: 6000 bits clear by the boundary, the rest pays
/// 100 Mb/s (60 µs) — tx end 66 µs, arrival 66 µs + the 100 µs latency
/// of the segment active at send time.
#[test]
fn boundary_straddling_transfer_matches_two_segment_closed_form() {
    let net = NetworkConfig::parse("up@1e9+100000:udp")
        .unwrap()
        .with_trace(
            LinkTrace::parse_chain("gigabit>slow@1e8+100000@6000ns")
                .unwrap(),
        );
    let mut ch = Channel::new(net);
    let r = ch.send(Dir::Up, 1472).unwrap();
    assert_eq!(r.sender_busy_ns(), 66_000);
    assert_eq!(r.busy_ns(), 166_000);
    // A message sent entirely inside the second segment pays its rate:
    // 12000 bits / 1e8 = 120 µs of serialization.
    ch.advance_to(1_000_000);
    let r2 = ch.send(Dir::Up, 1472).unwrap();
    assert_eq!(r2.sender_busy_ns(), 120_000);
}

/// Every committed suite entry parses into a non-constant single-hop
/// schedule, and a stream survives the handoff entry end-to-end.
#[test]
fn committed_suite_entries_parse_and_stream() {
    for entry in ["fade", "burst", "handoff", "degrading"] {
        let traces = parse_trace_arg(&suite_arg(entry)).unwrap();
        assert_eq!(traces.len(), 1, "{entry}");
        assert_eq!(traces[0].0, 0, "{entry}");
        assert!(!traces[0].1.is_constant(), "{entry}");
    }
    let engine = engine_for(Arch::Vgg16);
    let qos = QosRequirements::with_fps(20.0).unwrap();
    let mut scenario = ScenarioConfig {
        kind: ScenarioKind::Sc { split: 13 },
        hop_nets: vec![NetworkConfig::gigabit(Protocol::Udp, 0.0, 42)],
        tiers: vec![DeviceProfile::edge_gpu(), DeviceProfile::server_gpu()],
        scale: ModelScale::Slim,
        frame_period_ns: 50_000_000,
    };
    scenario
        .apply_traces(&parse_trace_arg(&suite_arg("handoff")).unwrap())
        .unwrap();
    let report = run_stream_with_queue(
        &*engine,
        &StreamConfig {
            scenario,
            clients: 1,
            frames_per_client: 8,
            batch: BatchPolicy::immediate(),
        },
        None,
        &qos,
        QueueKind::Calendar,
    )
    .unwrap();
    assert_eq!(report.records.len(), 8);
    assert!(report.mean_latency_ns > 0.0);
}

/// The acceptance bar of the adaptive controller, on the committed
/// degrading entry (good -> bad -> good handoff whose rates are derived
/// from VGG16's own latent volumetrics): both switch policies strictly
/// beat the best static cut chain's deadline hit-rate, stay strictly
/// below the zero-switchover-cost oracle, and the whole comparison is
/// byte-identical across event-queue backends.
#[test]
fn committed_degrading_suite_adaptive_beats_static_best() {
    let period: SimTime = 10_000_000; // 10 ms
    let frames = 60usize;
    let points = split_points(&Arch::Vgg16.full_network());
    // Mirror the suite's calibration: d = the shallowest candidate of the
    // smallest-latent group; the suite's good rate crosses the best
    // *shallow* latent in period/2, its bad rate in 1.35 periods.
    let n_cand = points.len() - 1;
    let min_bytes =
        (0..n_cand).map(|i| points[i].latent_bytes()).min().unwrap();
    let d = (0..n_cand)
        .find(|&i| points[i].latent_bytes() == min_bytes)
        .unwrap();
    let shallow_min_bytes =
        (0..d).map(|i| points[i].latent_bytes()).min().unwrap();
    let traces = parse_trace_arg(&suite_arg("degrading")).unwrap();
    let segs = traces[0].1.segments();
    assert_eq!(segs.len(), 3);
    let rg = shallow_min_bytes as f64 * 8.0 / (0.5 * period as f64 / 1e9);
    let rb = shallow_min_bytes as f64 * 8.0 / (1.35 * period as f64 / 1e9);
    assert!((segs[0].rate_bps() - rg).abs() / rg < 1e-6);
    assert!((segs[1].rate_bps() - rb).abs() / rb < 1e-6);
    assert_eq!(segs[1].start_ns, (frames as u64 * period) * 2 / 5);
    assert_eq!(segs[2].start_ns, (frames as u64 * period) * 7 / 10);
    // Edge tuned so d's head runs at 1.02 x period (same drift the
    // in-module scenario uses): deep is a poor static choice but an
    // affordable mid-stream visit.
    let (head_d, _) = points[d].split_compute();
    let overhead = 10_000u64;
    let macs =
        head_d as f64 / ((1.02 * period as f64 - overhead as f64) / 1e9);
    let base = NetworkConfig::parse("up@642252800+200000:udp").unwrap();
    let mut cfg = AdaptiveConfig {
        arch: Arch::Vgg16,
        scale: ModelScale::Full,
        tiers: vec![
            DeviceProfile::parse(&format!("edge@{macs:e}+{overhead}"))
                .unwrap(),
            DeviceProfile::parse("srv@1e15+1000").unwrap(),
        ],
        hop_nets: vec![base.with_trace(traces[0].1.clone())],
        frames,
        frame_period_ns: period,
        deadline_ns: period * 2,
        controller: ControllerConfig {
            window: 4,
            check_period_ns: period / 2,
            min_dwell_ns: 5 * period,
            switch_margin: 0.1,
        },
        queue: QueueKind::Calendar,
    };
    let r = run_adaptive_comparison(&cfg).unwrap();
    let sb = r.static_best_outcome();
    assert!(
        r.adaptive_drain.deadline_hit_rate > sb.deadline_hit_rate,
        "drain {} vs static-best {} ({})",
        r.adaptive_drain.deadline_hit_rate,
        sb.deadline_hit_rate,
        sb.label,
    );
    assert!(
        r.adaptive_drop.deadline_hit_rate > sb.deadline_hit_rate,
        "drop {} vs static-best {}",
        r.adaptive_drop.deadline_hit_rate,
        sb.deadline_hit_rate,
    );
    assert!(
        r.oracle.deadline_hit_rate > r.adaptive_drain.deadline_hit_rate,
        "oracle {} vs drain {}",
        r.oracle.deadline_hit_rate,
        r.adaptive_drain.deadline_hit_rate,
    );
    assert!(r.adaptive_drain.switches >= 1);
    // One candidate enumeration serves every controller decision.
    assert_eq!(r.chain_enumerations, 1);
    assert!(r.chain_lookups as usize > r.candidates.len());
    // Byte-identical across event-queue backends.
    cfg.queue = QueueKind::LinearScan;
    let r2 = run_adaptive_comparison(&cfg).unwrap();
    let eq = |a: &PolicyOutcome, b: &PolicyOutcome| {
        a.deadline_hit_rate == b.deadline_hit_rate
            && a.mean_latency_ns == b.mean_latency_ns
            && a.switches == b.switches
            && a.dropped == b.dropped
    };
    assert!(eq(&r.adaptive_drain, &r2.adaptive_drain));
    assert!(eq(&r.adaptive_drop, &r2.adaptive_drop));
    assert!(eq(&r.oracle, &r2.oracle));
    assert_eq!(r.static_best, r2.static_best);
}
