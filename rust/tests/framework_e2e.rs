//! End-to-end framework tests: scenario engine + netsim + inference
//! backend + QoS. Hermetic: they run on whatever `load_backend` resolves —
//! the real PJRT artifacts when built (feature `xla`), the analytic
//! reference backend otherwise — so they exercise the full pipeline on a
//! fresh checkout and in CI.

use std::path::Path;

use sei::coordinator::{
    self, CsCurve, ModelScale, QosRequirements, ScenarioConfig, ScenarioKind,
    SweepSpec,
};
use sei::model::{Arch, DeviceProfile};
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::runtime::{
    load_backend, load_backend_for, Executable, InferenceBackend,
};

fn engine() -> Box<dyn InferenceBackend> {
    load_backend(Path::new("artifacts")).expect("backend")
}

fn engine_for(arch: Arch) -> Box<dyn InferenceBackend> {
    load_backend_for(Path::new("artifacts"), arch).expect("backend")
}

fn cfg(kind: ScenarioKind, proto: Protocol, loss: f64) -> ScenarioConfig {
    ScenarioConfig::two_tier(
        kind,
        NetworkConfig::gigabit(proto, loss, 42),
        DeviceProfile::edge_gpu(),
        DeviceProfile::server_gpu(),
        ModelScale::Slim,
        50_000_000,
    )
}

#[test]
fn rc_tcp_accuracy_immune_to_loss() {
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let q = QosRequirements::none();
    let clean = coordinator::run_scenario(
        &*engine, &cfg(ScenarioKind::Rc, Protocol::Tcp, 0.0), &test, 64, &q,
    )
    .unwrap();
    let lossy = coordinator::run_scenario(
        &*engine, &cfg(ScenarioKind::Rc, Protocol::Tcp, 0.08), &test, 64, &q,
    )
    .unwrap();
    assert_eq!(clean.accuracy, lossy.accuracy, "TCP must protect accuracy");
    assert!(
        lossy.mean_latency_ns > clean.mean_latency_ns,
        "TCP must pay latency for loss"
    );
    assert!(lossy.total_retransmits > 0);
}

#[test]
fn rc_udp_accuracy_decays_latency_flat() {
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let q = QosRequirements::none();
    let clean = coordinator::run_scenario(
        &*engine, &cfg(ScenarioKind::Rc, Protocol::Udp, 0.0), &test, 96, &q,
    )
    .unwrap();
    let lossy = coordinator::run_scenario(
        &*engine, &cfg(ScenarioKind::Rc, Protocol::Udp, 0.35), &test, 96, &q,
    )
    .unwrap();
    assert!(
        lossy.accuracy < clean.accuracy,
        "UDP corruption must cost accuracy: {} vs {}",
        lossy.accuracy,
        clean.accuracy
    );
    // Latency is identical (same seed, loss-independent schedule).
    assert!(
        (lossy.mean_latency_ns - clean.mean_latency_ns).abs()
            < 0.01 * clean.mean_latency_ns,
        "UDP latency should not depend on loss"
    );
}

#[test]
fn sc_beats_rc_on_wire_bytes_at_deep_split() {
    let engine = engine();
    let splits = engine.manifest().available_splits();
    let split = *splits.last().unwrap();
    let test = engine.dataset("test").unwrap();
    let q = QosRequirements::none();
    let rc = coordinator::run_scenario(
        &*engine, &cfg(ScenarioKind::Rc, Protocol::Tcp, 0.0), &test, 32, &q,
    )
    .unwrap();
    let sc = coordinator::run_scenario(
        &*engine,
        &cfg(ScenarioKind::Sc { split }, Protocol::Tcp, 0.0),
        &test,
        32,
        &q,
    )
    .unwrap();
    assert!(
        sc.mean_wire_bytes < rc.mean_wire_bytes,
        "deep split must compress the wire: SC {} vs RC {}",
        sc.mean_wire_bytes,
        rc.mean_wire_bytes
    );
    // And keeps most of the accuracy.
    assert!(sc.accuracy > rc.accuracy - 0.1);
}

#[test]
fn lc_runs_without_network() {
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let q = QosRequirements::ice_lab();
    let lc = coordinator::run_scenario(
        &*engine, &cfg(ScenarioKind::Lc, Protocol::Tcp, 0.5), &test, 48, &q,
    )
    .unwrap();
    assert_eq!(lc.mean_wire_bytes, 0.0);
    assert_eq!(lc.total_retransmits, 0);
    assert!(lc.accuracy > 0.5, "lite model should beat chance by far");
}

#[test]
fn suggestion_engine_ranks_and_simulates() {
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let qos = QosRequirements::ice_lab();
    let suggestions = coordinator::suggest(
        &*engine,
        &NetworkConfig::gigabit(Protocol::Tcp, 0.02, 7),
        &[DeviceProfile::edge_gpu(), DeviceProfile::server_gpu()],
        &qos,
        &test,
        48,
        2,
    )
    .unwrap();
    // Must include the LC and RC baselines plus >= 1 SC candidate.
    assert!(suggestions.len() >= 3);
    let kinds: Vec<String> =
        suggestions.iter().map(|s| s.rank.kind.to_string()).collect();
    assert!(kinds.iter().any(|k| k == "LC"));
    assert!(kinds.iter().any(|k| k == "RC"));
    assert!(kinds.iter().any(|k| k.starts_with("SC@")));
    // Ranking is by predicted accuracy, descending.
    for w in suggestions.windows(2) {
        assert!(
            w[0].rank.predicted_accuracy >= w[1].rank.predicted_accuracy
        );
    }
    let best = coordinator::best(&suggestions).unwrap();
    assert!(best.report.frames == 48);
}

#[test]
fn rust_cs_curve_agrees_with_manifest_on_shape() {
    let engine = engine();
    if engine.manifest().gradcam_layers().len() < 6 {
        return; // fast artifacts
    }
    let test = engine.dataset("test").unwrap();
    let rust_curve =
        coordinator::saliency::compute_cs_curve(&*engine, &test, 64)
            .unwrap();
    let python_curve = CsCurve::from_manifest(engine.manifest());
    let r = rust_curve.normalized();
    let p = python_curve.normalized();
    assert_eq!(r.len(), p.len());
    // Same subset of images differs from python's 512, so compare shape:
    // rank correlation between the two curves must be strongly positive.
    let n = r.len() as f64;
    let rank = |v: &[f64]| -> Vec<f64> {
        let mut idx: Vec<usize> = (0..v.len()).collect();
        idx.sort_by(|&a, &b| v[a].partial_cmp(&v[b]).unwrap());
        let mut out = vec![0.0; v.len()];
        for (rank, &i) in idx.iter().enumerate() {
            out[i] = rank as f64;
        }
        out
    };
    let (ra, rb) = (rank(&r), rank(&p));
    let d2: f64 = ra.iter().zip(&rb).map(|(a, b)| (a - b) * (a - b)).sum();
    let spearman = 1.0 - 6.0 * d2 / (n * (n * n - 1.0));
    assert!(
        spearman > 0.7,
        "rust vs python CS curves disagree: spearman {spearman:.3}\n\
         rust:   {r:?}\npython: {p:?}"
    );
}

#[test]
fn serve_reports_wall_and_sim_throughput() {
    let engine = engine();
    let ice = engine.dataset("ice").unwrap();
    let qos = QosRequirements::ice_lab();
    let splits = engine.manifest().available_splits();
    let c = cfg(
        ScenarioKind::Sc { split: *splits.last().unwrap() },
        Protocol::Tcp,
        0.01,
    );
    let r = coordinator::serve(&*engine, &c, &ice, 40, &qos).unwrap();
    assert_eq!(r.frames, 40);
    assert!(r.wall_seconds > 0.0);
    assert!(r.sim_fps > 0.0);
    let txt = r.render(&qos);
    assert!(txt.contains("VERDICT"));
}

#[test]
fn paper_scale_fig3_shape_holds() {
    // Fig. 3 end-to-end at paper scale: SC@L15 meets 20 FPS across loss
    // rates; SC@L11 violates beyond a few percent.
    let engine = engine();
    let splits = engine.manifest().available_splits();
    if !splits.contains(&11) || !splits.contains(&15) {
        return;
    }
    let mean = |split: usize, loss: f64| -> f64 {
        let c = ScenarioConfig::two_tier(
            ScenarioKind::Sc { split },
            NetworkConfig::gigabit(Protocol::Tcp, loss, 11),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            ModelScale::Full,
            50_000_000,
        );
        let lats = coordinator::simulate_latency(&*engine, &c, 200)
            .unwrap();
        lats.iter().map(|v| *v as f64).sum::<f64>() / lats.len() as f64
    };
    let budget = 50e6;
    assert!(mean(11, 0.0) < budget);
    assert!(mean(15, 0.0) < budget);
    // Paper shape: L15 robust well past the loss rate where L11 breaks.
    assert!(mean(15, 0.06) < budget, "L15 must hold at 6% loss");
    assert!(mean(11, 0.08) > budget, "L11 must violate by 8% loss");
    assert!(
        mean(11, 0.08) > mean(15, 0.08),
        "L11 must degrade faster than L15"
    );
}

#[test]
fn suggest_ranks_dag_cuts_for_resnet_and_mobilenet() {
    // The acceptance check of the model-IR refactor: `suggest` on a
    // skip-connection architecture returns non-trivial cut rankings —
    // SC candidates exist, carry block-boundary cut names, and every
    // offered split id is one of the arch's valid (non-interior) cuts.
    for (arch, name_prefixes) in [
        (Arch::ResNet18, &["layer", "maxpool", "conv1"][..]),
        (Arch::MobileNetV2, &["block", "stem", "head"][..]),
    ] {
        let engine = engine_for(arch);
        let test = engine.dataset("test").unwrap();
        let qos = QosRequirements::ice_lab();
        let suggestions = coordinator::suggest(
            &*engine,
            &NetworkConfig::gigabit(Protocol::Tcp, 0.0, 7),
            &[DeviceProfile::edge_gpu(), DeviceProfile::server_gpu()],
            &qos,
            &test,
            32,
            2,
        )
        .unwrap();
        let sc: Vec<_> = suggestions
            .iter()
            .filter(|s| matches!(s.rank.kind, ScenarioKind::Sc { .. }))
            .collect();
        assert!(sc.len() >= 2, "{arch:?}: {} SC candidates", sc.len());
        let n_cuts = engine.manifest().model.layer_names.len();
        for s in &sc {
            let ScenarioKind::Sc { split } = &s.rank.kind else {
                unreachable!()
            };
            assert!(*split < n_cuts - 1, "{arch:?} split {split}");
            let cut = s.rank.cut_name.as_deref().unwrap();
            assert!(
                name_prefixes.iter().any(|p| cut.starts_with(p)),
                "{arch:?}: unexpected cut name '{cut}'"
            );
            assert!(s.report.frames == 32);
            assert!(s.report.accuracy > 0.5);
        }
    }
}

#[test]
fn arch_sweep_pareto_frontier_spans_architectures() {
    // Architecture as a design axis: at paper scale the zoo trades
    // accuracy (VGG16 highest) against compute (MobileNetV2 ~50x
    // cheaper), so the accuracy-vs-latency frontier of a cross-arch RC
    // sweep must retain at least two different architectures.
    let mut spec = SweepSpec::new("arch-pareto");
    spec.scenarios = vec![ScenarioKind::Rc];
    spec.protocols = vec![Protocol::Tcp];
    spec.loss_rates = vec![0.0];
    spec.scales = vec![ModelScale::Full];
    spec.archs = vec![Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2];
    spec.frames = 192;
    spec.seeds_per_point = 2;
    let report = coordinator::run_sweep(&spec, 2, &|arch| {
        load_backend_for(Path::new("artifacts"), arch)
    })
    .unwrap();
    assert_eq!(report.points.len(), 3);
    // Latency strictly follows model size at paper scale.
    let lat = |a: Arch| {
        report
            .points
            .iter()
            .find(|p| p.arch == a)
            .unwrap()
            .mean_latency_ns
    };
    assert!(lat(Arch::MobileNetV2) < lat(Arch::ResNet18));
    assert!(lat(Arch::ResNet18) < lat(Arch::Vgg16));
    let frontier_archs: std::collections::BTreeSet<&str> = report
        .pareto
        .iter()
        .map(|&i| report.points[i].arch.as_str())
        .collect();
    assert!(
        frontier_archs.len() >= 2,
        "frontier holds one arch only: {frontier_archs:?}"
    );
}

#[test]
fn hil_worker_round_trip_with_real_artifacts() {
    // The hardware-in-the-loop path: a worker thread serves the tail over
    // a real localhost TCP socket; the leader runs the head locally.
    let engine = engine();
    let splits = engine.manifest().available_splits();
    let split = *splits.first().unwrap();
    let addr = {
        let probe = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        probe.local_addr().unwrap().to_string()
    };
    let worker_addr = addr.clone();
    let worker = std::thread::spawn(move || {
        sei::coordinator::hil::run_worker(
            Path::new("artifacts"),
            &worker_addr,
            &format!("tail_L{split}_b1"),
        )
    });
    let test = engine.dataset("test").unwrap();
    let head = engine.executable(&format!("head_L{split}_b1")).unwrap();
    let mut client =
        sei::coordinator::hil::HilClient::connect(&addr).unwrap();
    let n = 24usize;
    let mut correct = 0;
    for i in 0..n {
        let x = test.batch(i, 1).unwrap();
        let z = head.run(&[sei::runtime::RtInput::F32(&x)]).unwrap();
        let logits = client
            .infer(&z, vec![1, engine.manifest().model.num_classes])
            .unwrap();
        if logits.argmax_last()[0] == test.labels[i] as usize {
            correct += 1;
        }
    }
    assert_eq!(client.rtts_ns.len(), n);
    assert!(client.mean_rtt_ns() > 0.0);
    client.shutdown().unwrap();
    assert_eq!(worker.join().unwrap().unwrap(), n as u64);
    // Accuracy over the real socket must match the in-process path.
    let expected = engine
        .manifest()
        .split_eval_for(split)
        .map(|r| r.accuracy)
        .unwrap_or(0.9);
    assert!(
        (correct as f64 / n as f64 - expected).abs() < 0.2,
        "HIL accuracy {correct}/{n} vs expected {expected:.2}"
    );
}

#[test]
fn batched_tail_pipeline_matches_unbatched() {
    // Workload -> batcher -> b16 tail must classify identically to the
    // one-by-one b1 tail.
    use sei::coordinator::batcher::{BatchPolicy, Batcher};
    use sei::coordinator::workload::{ArrivalProcess, Workload};
    let engine = engine();
    let splits = engine.manifest().available_splits();
    let split = *splits.last().unwrap();
    let test = engine.dataset("test").unwrap();
    let head16 =
        engine.executable(&format!("head_L{split}_b16")).unwrap();
    let tail1 = engine.executable(&format!("tail_L{split}_b1")).unwrap();
    let tail16 = engine.executable(&format!("tail_L{split}_b16")).unwrap();

    let x = test.batch(0, 16).unwrap();
    let z = head16.run(&[sei::runtime::RtInput::F32(&x)]).unwrap();

    // Unbatched predictions.
    let mut unbatched = Vec::new();
    for i in 0..16 {
        let zi = z.slice_rows(i, 1).unwrap();
        let logits = tail1.run(&[sei::runtime::RtInput::F32(&zi)]).unwrap();
        unbatched.push(logits.argmax_last()[0]);
    }

    // Batched: drive the batcher with a Poisson workload until the size
    // trigger fires, then run the b16 artifact once.
    let mut batcher = Batcher::new(BatchPolicy::new(16, 50_000_000));
    let mut wl = Workload::new(ArrivalProcess::Poisson { fps: 500.0 }, 3);
    let mut released = None;
    for _ in 0..16 {
        let t = wl.next_arrival();
        if let Some(b) = batcher.offer(t) {
            released = Some(b);
        }
    }
    let batch = released.expect("size trigger at 16");
    assert_eq!(batch.len(), 16);
    let logits = tail16.run(&[sei::runtime::RtInput::F32(&z)]).unwrap();
    let batched = logits.argmax_last();
    assert_eq!(batched, unbatched, "batched vs unbatched predictions");
}
