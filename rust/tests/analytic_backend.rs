//! Hermetic end-to-end determinism tests for the analytic backend: the
//! acceptance contract behind `sei suggest` / `sei simulate` running on a
//! fresh checkout with no artifacts and no XLA — results must be
//! bit-stable across backend instances for a given seed.

use std::path::Path;

use sei::coordinator::{
    self, ModelScale, QosRequirements, ScenarioConfig, ScenarioKind,
};
use sei::model::DeviceProfile;
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::runtime::{load_backend, InferenceBackend};

fn backend() -> Box<dyn InferenceBackend> {
    load_backend(Path::new("artifacts")).expect("backend")
}

fn cfg(kind: ScenarioKind, seed: u64) -> ScenarioConfig {
    ScenarioConfig::two_tier(
        kind,
        NetworkConfig::gigabit(Protocol::Tcp, 0.02, seed),
        DeviceProfile::edge_gpu(),
        DeviceProfile::server_gpu(),
        ModelScale::Slim,
        50_000_000,
    )
}

#[test]
fn scenario_reports_are_reproducible_across_backends() {
    let qos = QosRequirements::ice_lab();
    let mut runs = Vec::new();
    for _ in 0..2 {
        let engine = backend();
        let test = engine.dataset("test").unwrap();
        let r = coordinator::run_scenario(
            &*engine,
            &cfg(ScenarioKind::Rc, 7),
            &test,
            64,
            &qos,
        )
        .unwrap();
        runs.push((r.accuracy, r.mean_latency_ns, r.mean_wire_bytes,
                   r.total_retransmits));
    }
    assert_eq!(runs[0], runs[1], "same seed must reproduce exactly");
}

#[test]
fn suggestion_table_is_reproducible() {
    let qos = QosRequirements::with_fps(20.0).unwrap();
    let table = |_: usize| -> Vec<(String, f64, f64, bool)> {
        let engine = backend();
        let test = engine.dataset("test").unwrap();
        coordinator::suggest(
            &*engine,
            &NetworkConfig::gigabit(Protocol::Tcp, 0.02, 7),
            &[DeviceProfile::edge_gpu(), DeviceProfile::server_gpu()],
            &qos,
            &test,
            32,
            2,
        )
        .unwrap()
        .iter()
        .map(|s| {
            (
                s.rank.kind.to_string(),
                s.report.accuracy,
                s.report.mean_latency_ns,
                s.satisfies,
            )
        })
        .collect()
    };
    assert_eq!(table(0), table(1));
}

#[test]
fn different_channel_seeds_change_lossy_latency() {
    let engine = backend();
    let test = engine.dataset("test").unwrap();
    let qos = QosRequirements::none();
    let lat = |seed: u64| {
        coordinator::run_scenario(
            &*engine,
            &cfg(ScenarioKind::Rc, seed),
            &test,
            64,
            &qos,
        )
        .unwrap()
        .mean_latency_ns
    };
    assert_ne!(lat(1), lat(2), "channel seed must drive the saboteur");
}

#[test]
fn default_backend_is_hermetic_without_artifacts() {
    // On a fresh checkout (no artifacts/) the default feature set must
    // yield a fully usable backend.
    let engine = backend();
    if engine.name() == "analytic" {
        assert!(!engine.manifest().available_splits().is_empty());
        assert_eq!(engine.dataset("ice").unwrap().name, "ice");
    }
}
