//! Multi-tier placement acceptance tests.
//!
//! The correctness anchor of the k-cut refactor is **degenerate
//! equivalence**: `mc@[i]` over two tiers must reproduce `sc@i`
//! byte-identically — same per-frame latency, wire bytes, retransmits,
//! corruption flags and accuracy — for every exported cut of every
//! architecture, under both transports. Beyond the anchor: three-tier
//! chains run end-to-end (hermetically, on the analytic backend's
//! on-demand segment executables), corruption on any hop costs accuracy,
//! a slow mid-chain tier queues like any other bottleneck, and the sweep
//! engine's thread-count determinism survives the new `tiers` /
//! `cut_chains` axes.

use std::path::Path;

use sei::coordinator::{
    self, ModelScale, QosRequirements, ScenarioConfig, ScenarioKind,
    SweepSpec,
};
use sei::model::{Arch, DeviceProfile};
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::runtime::{load_backend_for, InferenceBackend};

fn engine_for(arch: Arch) -> Box<dyn InferenceBackend> {
    // No artifacts directory in tests: loads the hermetic analytic backend.
    load_backend_for(Path::new("artifacts"), arch).expect("backend")
}

fn two_tier(kind: ScenarioKind, proto: Protocol, loss: f64)
    -> ScenarioConfig
{
    ScenarioConfig::two_tier(
        kind,
        NetworkConfig::gigabit(proto, loss, 42),
        DeviceProfile::edge_gpu(),
        DeviceProfile::server_gpu(),
        ModelScale::Slim,
        50_000_000,
    )
}

fn three_tier(cuts: Vec<usize>, proto: Protocol, loss: f64)
    -> ScenarioConfig
{
    ScenarioConfig {
        kind: ScenarioKind::Mc { cuts },
        hop_nets: vec![NetworkConfig::gigabit(proto, loss, 42)],
        tiers: vec![
            DeviceProfile::sensor_npu(),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
        ],
        scale: ModelScale::Slim,
        frame_period_ns: 50_000_000,
    }
}

#[test]
fn mc_single_cut_reproduces_sc_byte_identically() {
    // Every exported cut of every arch, both transports, with loss: the
    // one-cut chain and the classic split must be indistinguishable.
    for arch in Arch::ALL {
        let engine = engine_for(arch);
        let test = engine.dataset("test").unwrap();
        let qos = QosRequirements::ice_lab();
        for split in engine.manifest().available_splits() {
            for (proto, loss) in
                [(Protocol::Tcp, 0.03), (Protocol::Udp, 0.08)]
            {
                let sc = coordinator::run_scenario(
                    &*engine,
                    &two_tier(ScenarioKind::Sc { split }, proto, loss),
                    &test,
                    20,
                    &qos,
                )
                .unwrap();
                let mc = coordinator::run_scenario(
                    &*engine,
                    &two_tier(
                        ScenarioKind::Mc { cuts: vec![split] },
                        proto,
                        loss,
                    ),
                    &test,
                    20,
                    &qos,
                )
                .unwrap();
                assert_eq!(sc.frames, mc.frames);
                assert_eq!(
                    sc.accuracy, mc.accuracy,
                    "{arch:?} L{split} {proto} accuracy"
                );
                for (i, (a, b)) in
                    sc.records.iter().zip(&mc.records).enumerate()
                {
                    assert_eq!(
                        a.latency_ns, b.latency_ns,
                        "{arch:?} L{split} {proto} frame {i} latency"
                    );
                    assert_eq!(a.completed_ns, b.completed_ns);
                    assert_eq!(a.wire_bytes, b.wire_bytes);
                    assert_eq!(a.retransmits, b.retransmits);
                    assert_eq!(a.corrupted, b.corrupted);
                    assert_eq!(a.correct, b.correct);
                }
            }
        }
    }
}

#[test]
fn mc_single_cut_matches_sc_in_latency_only_mode_too() {
    let engine = engine_for(Arch::Vgg16);
    for scale in [ModelScale::Slim, ModelScale::Full] {
        for (proto, loss) in [(Protocol::Tcp, 0.02), (Protocol::Udp, 0.0)] {
            let mut sc = two_tier(ScenarioKind::Sc { split: 11 }, proto, loss);
            sc.scale = scale;
            let mut mc =
                two_tier(ScenarioKind::Mc { cuts: vec![11] }, proto, loss);
            mc.scale = scale;
            assert_eq!(
                coordinator::simulate_latency(&*engine, &sc, 32).unwrap(),
                coordinator::simulate_latency(&*engine, &mc, 32).unwrap(),
                "{scale:?} {proto} loss {loss}"
            );
        }
    }
}

#[test]
fn three_tier_chain_serves_end_to_end_with_real_inference() {
    // Sensor -> edge -> cloud with two cuts: the analytic backend
    // synthesizes the mid re-encoder and the composed chain tail on
    // demand, and the chain classifies nearly as well as the full model
    // (the composition of signed folds is itself a signed fold).
    let engine = engine_for(Arch::Vgg16);
    let test = engine.dataset("test").unwrap();
    let qos = QosRequirements::none();
    let cfg = three_tier(vec![5, 13], Protocol::Tcp, 0.0);
    let r = coordinator::run_scenario(&*engine, &cfg, &test, 64, &qos)
        .unwrap();
    assert_eq!(r.frames, 64);
    let base = engine.manifest().model.base_test_accuracy;
    assert!(
        r.accuracy > base - 0.12,
        "3-tier chain accuracy {} collapsed vs base {base}",
        r.accuracy
    );
    // Two uplink hops: more wire traffic than the deeper single split,
    // and every frame's result comes back over both downlinks.
    let one = coordinator::run_scenario(
        &*engine,
        &two_tier(ScenarioKind::Sc { split: 13 }, Protocol::Tcp, 0.0),
        &test,
        64,
        &qos,
    )
    .unwrap();
    assert!(r.mean_wire_bytes > one.mean_wire_bytes);
    assert!(r.mean_latency_ns > 0.0);
}

#[test]
fn udp_loss_on_a_multi_tier_chain_costs_accuracy() {
    let engine = engine_for(Arch::Vgg16);
    let test = engine.dataset("test").unwrap();
    let qos = QosRequirements::none();
    let clean = coordinator::run_scenario(
        &*engine,
        &three_tier(vec![5, 13], Protocol::Udp, 0.0),
        &test,
        96,
        &qos,
    )
    .unwrap();
    let lossy = coordinator::run_scenario(
        &*engine,
        &three_tier(vec![5, 13], Protocol::Udp, 0.30),
        &test,
        96,
        &qos,
    )
    .unwrap();
    assert!(
        lossy.accuracy < clean.accuracy,
        "corruption on the chain must cost accuracy: {} vs {}",
        lossy.accuracy,
        clean.accuracy
    );
    // UDP latency stays loss-independent, hop by hop.
    assert!(
        (lossy.mean_latency_ns - clean.mean_latency_ns).abs()
            < 0.01 * clean.mean_latency_ns
    );
}

#[test]
fn slow_mid_tier_queues_like_any_bottleneck() {
    // The same chain with a microcontroller-class middle tier must show
    // strictly higher latency, and under offered load its queue builds.
    let engine = engine_for(Arch::Vgg16);
    let fast = coordinator::simulate_latency(
        &*engine,
        &three_tier(vec![5, 9], Protocol::Udp, 0.0),
        16,
    )
    .unwrap();
    let mut slow_cfg = three_tier(vec![5, 9], Protocol::Udp, 0.0);
    slow_cfg.tiers[1] = DeviceProfile::sensor_mcu();
    let slow = coordinator::simulate_latency(&*engine, &slow_cfg, 16)
        .unwrap();
    for (f, s) in fast.iter().zip(&slow) {
        assert!(s > f, "slow mid tier must cost latency: {s} vs {f}");
    }
    // Offered faster than the weak tier can serve: closed-loop queueing
    // shows up as growing per-frame latency.
    slow_cfg.frame_period_ns = 1_000_000; // 1000 FPS offered
    let overloaded =
        coordinator::simulate_latency(&*engine, &slow_cfg, 24).unwrap();
    assert!(overloaded.last().unwrap() > overloaded.first().unwrap());
}

#[test]
fn heterogeneous_hop_nets_latency_sits_between_homogeneous_baselines() {
    // wifi -> gigabit on a sensor -> edge -> cloud chain: the mixed
    // channel assignment must cost strictly more than all-gigabit (its
    // slow hop is real) and strictly less than all-wifi (its fast hop is
    // real too).
    let engine = engine_for(Arch::Vgg16);
    let test = engine.dataset("test").unwrap();
    let qos = QosRequirements::none();
    let run = |hop_nets: Vec<NetworkConfig>| {
        let cfg = ScenarioConfig {
            kind: ScenarioKind::Mc { cuts: vec![5, 13] },
            hop_nets,
            tiers: vec![
                DeviceProfile::sensor_npu(),
                DeviceProfile::edge_gpu(),
                DeviceProfile::server_gpu(),
            ],
            scale: ModelScale::Slim,
            frame_period_ns: 50_000_000,
        };
        coordinator::run_scenario(&*engine, &cfg, &test, 16, &qos)
            .unwrap()
            .mean_latency_ns
    };
    let wifi = NetworkConfig::wifi(Protocol::Tcp, 0.0, 42);
    let gigabit = NetworkConfig::gigabit(Protocol::Tcp, 0.0, 42);
    let all_wifi = run(vec![wifi.clone()]);
    let all_gigabit = run(vec![gigabit.clone()]);
    let mixed = run(vec![wifi, gigabit]);
    assert!(
        all_gigabit < mixed && mixed < all_wifi,
        "heterogeneous chain latency must sit strictly between the \
         homogeneous baselines: gigabit {all_gigabit} | mixed {mixed} | \
         wifi {all_wifi}"
    );
}

#[test]
fn single_entry_hop_nets_replicates_the_template_byte_identically() {
    // The backward-compat rule: one hop_nets entry is a template — hop 0
    // keeps its seed verbatim, deeper hops derive theirs. Spelling the
    // derived per-hop channels out explicitly (ScenarioConfig::hop_net)
    // must reproduce the template run byte-for-byte, for chains over
    // every exported cut under both transports (UDP loss exercises the
    // per-hop corruption RNG, so a seed regression cannot hide).
    let engine = engine_for(Arch::Vgg16);
    let test = engine.dataset("test").unwrap();
    let qos = QosRequirements::none();
    let splits = engine.manifest().available_splits();
    for pair in splits.windows(2) {
        for (proto, loss) in [(Protocol::Tcp, 0.03), (Protocol::Udp, 0.10)]
        {
            let template = three_tier(pair.to_vec(), proto, loss);
            let explicit = ScenarioConfig {
                hop_nets: (0..2).map(|h| template.hop_net(h)).collect(),
                ..template.clone()
            };
            let a = coordinator::run_scenario(
                &*engine, &template, &test, 12, &qos,
            )
            .unwrap();
            let b = coordinator::run_scenario(
                &*engine, &explicit, &test, 12, &qos,
            )
            .unwrap();
            assert_eq!(a.accuracy, b.accuracy, "{pair:?} {proto}");
            for (i, (x, y)) in a.records.iter().zip(&b.records).enumerate()
            {
                assert_eq!(x.latency_ns, y.latency_ns, "{pair:?} frame {i}");
                assert_eq!(x.completed_ns, y.completed_ns);
                assert_eq!(x.wire_bytes, y.wire_bytes);
                assert_eq!(x.retransmits, y.retransmits);
                assert_eq!(x.corrupted, y.corrupted);
                assert_eq!(x.correct, y.correct);
            }
        }
    }
}

#[test]
fn fleet_placement_smoke_is_thread_count_invariant() {
    // The shipped example fleet: the search returns a plan, and the plan
    // JSON is byte-identical at 1 and 8 worker threads (CI re-checks this
    // through the CLI).
    let text = std::fs::read_to_string(
        Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("../examples/specs/fleet.json"),
    )
    .expect("examples/specs/fleet.json");
    let mut fleet = coordinator::FleetSpec::from_json(&text).unwrap();
    fleet.frames = 6; // keep the smoke fast; determinism is the point
    let factory = |arch| load_backend_for(Path::new("artifacts"), arch);
    let one = coordinator::place(&fleet, 1, &factory).unwrap();
    let eight = coordinator::place(&fleet, 8, &factory).unwrap();
    assert_eq!(
        one.plan.to_json().to_string(),
        eight.plan.to_json().to_string(),
        "placement plan must not depend on the thread count"
    );
    assert!(one.plan.satisfied >= 1, "example fleet must serve a stream");
    assert_eq!(one.plan.hop_links.len(), one.plan.cuts.len());
}

#[test]
fn suggest_ranks_multi_tier_chains_against_qos() {
    let engine = engine_for(Arch::Vgg16);
    let test = engine.dataset("test").unwrap();
    let qos = QosRequirements::ice_lab();
    let tiers = [
        DeviceProfile::sensor_npu(),
        DeviceProfile::edge_gpu(),
        DeviceProfile::server_gpu(),
    ];
    let suggestions = coordinator::suggest(
        &*engine,
        &NetworkConfig::gigabit(Protocol::Tcp, 0.0, 7),
        &tiers,
        &qos,
        &test,
        24,
        2,
    )
    .unwrap();
    let mc: Vec<_> = suggestions
        .iter()
        .filter(|s| matches!(s.rank.kind, ScenarioKind::Mc { .. }))
        .collect();
    assert!(!mc.is_empty(), "3-tier suggest must rank MC chains");
    for s in &mc {
        assert_eq!(s.report.frames, 24);
        assert!(s.report.accuracy > 0.5, "{}", s.rank.kind);
        assert!(s.rank.cut_name.as_deref().unwrap().contains('>'));
    }
    // LC/RC/SC baselines still present alongside the chains.
    let kinds: Vec<String> =
        suggestions.iter().map(|s| s.rank.kind.to_string()).collect();
    assert!(kinds.iter().any(|k| k == "LC"));
    assert!(kinds.iter().any(|k| k == "RC"));
    assert!(kinds.iter().any(|k| k.starts_with("SC@")));
}

#[test]
fn tier_axes_sweep_is_thread_count_invariant() {
    // The headline sweep guarantee survives the tiers / cut_chains axes:
    // byte-identical JSON and CSV at every worker-thread count.
    let mut spec = SweepSpec::new("tier-determinism");
    spec.scenarios = vec![ScenarioKind::Rc, ScenarioKind::Sc { split: 13 }];
    spec.protocols = vec![Protocol::Tcp, Protocol::Udp];
    spec.loss_rates = vec![0.0, 0.05];
    spec.tiers = vec![
        vec!["edge-gpu".into(), "server-gpu".into()],
        vec!["sensor-npu".into(), "edge-gpu".into(), "server-gpu".into()],
    ];
    spec.cut_chains = vec![vec![5, 13], vec![9, 13]];
    spec.frames = 8;
    spec.max_latency_ms = 50.0;
    spec.min_accuracy = 0.9;
    let factory = |arch| load_backend_for(Path::new("artifacts"), arch);
    let one = coordinator::run_sweep(&spec, 1, &factory).unwrap();
    let eight = coordinator::run_sweep(&spec, 8, &factory).unwrap();
    // RC/SC run on both chains; each MC chain pairs with the 3-tier one.
    assert_eq!(one.points.len(), (2 * 2 + 2) * 2 * 2);
    assert_eq!(
        one.to_json().to_string(),
        eight.to_json().to_string(),
        "tier-axis sweep JSON must not depend on the thread count"
    );
    assert_eq!(one.to_csv().to_string(), eight.to_csv().to_string());
    // Every point reports its tier chain; MC points carry three tiers.
    for p in &one.points {
        assert!(p.tiers.len() >= 2);
        if let ScenarioKind::Mc { cuts } = &p.kind {
            assert_eq!(p.tiers.len(), cuts.len() + 1);
            assert!(p.accuracy.is_some());
        }
    }
    let csv = one.to_csv().to_string();
    assert!(csv.contains("sensor-npu>edge-gpu>server-gpu"));
}
