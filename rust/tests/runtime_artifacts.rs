//! Integration tests over the loaded inference backend: the real AOT
//! artifacts (PJRT execution, feature `xla`) when `artifacts/` has been
//! built, the hermetic analytic reference backend otherwise — both must
//! satisfy the same executable-level contract.

use std::path::Path;

use sei::runtime::{load_backend, Executable, InferenceBackend, RtInput};

fn engine() -> Box<dyn InferenceBackend> {
    load_backend(Path::new("artifacts")).expect("backend")
}

#[test]
fn full_forward_matches_python_fixture() {
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let exec = engine.executable("full_fwd_b16").unwrap();
    let x = test.batch(0, 16).unwrap();
    let got = exec.run(&[RtInput::F32(&x)]).unwrap();
    let want = engine.fixture("test16_logits").unwrap();
    assert_eq!(got.shape(), want.shape());
    for (g, w) in got.data().iter().zip(want.data()) {
        assert!(
            (g - w).abs() <= 1e-4 * (1.0 + w.abs()),
            "logit mismatch: {g} vs {w}"
        );
    }
}

#[test]
fn pallas_artifact_matches_jnp_artifact() {
    // The L1 Pallas conv path and the jnp conv path must agree when run
    // by the Rust runtime (not just under pytest). On the analytic
    // backend both names resolve to the same deterministic model.
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let jnp = engine.executable("full_fwd_b16").unwrap();
    let pallas = engine.executable("full_fwd_pallas_b4").unwrap();
    let x16 = test.batch(0, 16).unwrap();
    let x4 = test.batch(0, 4).unwrap();
    let a = jnp.run(&[RtInput::F32(&x16)]).unwrap();
    let b = pallas.run(&[RtInput::F32(&x4)]).unwrap();
    for row in 0..4 {
        for c in 0..10 {
            let va = a.data()[row * 10 + c];
            let vb = b.data()[row * 10 + c];
            assert!(
                (va - vb).abs() <= 2e-3 * (1.0 + va.abs()),
                "pallas/jnp divergence at [{row},{c}]: {va} vs {vb}"
            );
        }
    }
}

#[test]
fn head_tail_compose_to_sane_accuracy() {
    // Run head -> tail at each exported split over a test slice; accuracy
    // must be close to the python-recorded split accuracy.
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let n = 96usize;
    for split in engine.manifest().available_splits() {
        let head = engine
            .executable(&format!("head_L{split}_b16"))
            .unwrap();
        let tail = engine
            .executable(&format!("tail_L{split}_b16"))
            .unwrap();
        let mut correct = 0usize;
        let mut start = 0;
        while start + 16 <= n {
            let x = test.batch(start, 16).unwrap();
            let z = head.run(&[RtInput::F32(&x)]).unwrap();
            let logits = tail.run(&[RtInput::F32(&z)]).unwrap();
            for (p, l) in logits
                .argmax_last()
                .iter()
                .zip(test.batch_labels(start, 16))
            {
                if *p == *l as usize {
                    correct += 1;
                }
            }
            start += 16;
        }
        let acc = correct as f64 / n as f64;
        let expected = engine
            .manifest()
            .split_eval_for(split)
            .map(|r| r.accuracy)
            .unwrap_or(0.9);
        assert!(
            (acc - expected).abs() < 0.12,
            "split L{split}: rust acc {acc:.3} vs python {expected:.3}"
        );
    }
}

#[test]
fn head_output_matches_declared_latent_shape() {
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let splits = engine.manifest().available_splits();
    let split = *splits.first().unwrap();
    let head = engine.executable(&format!("head_L{split}_b1")).unwrap();
    let x = test.batch(0, 1).unwrap();
    let z = head.run(&[RtInput::F32(&x)]).unwrap();
    let want =
        engine.manifest().split_eval_for(split).unwrap().latent_shape;
    assert_eq!(z.shape(), &[1, want[0], want[1], want[2]]);
    // 50% compression vs the raw feature map.
    let feat = engine.manifest().model.feature_shapes[split];
    assert_eq!(want[0] * 2, feat[0]);
}

#[test]
fn gradcam_artifact_runs_and_is_nonnegative() {
    let engine = engine();
    let layers = engine.manifest().gradcam_layers();
    if layers.is_empty() {
        return;
    }
    let test = engine.dataset("test").unwrap();
    let li = layers[layers.len() / 2];
    let exec = engine.executable(&format!("gradcam_L{li}_b16")).unwrap();
    let x = test.batch(0, 16).unwrap();
    let y = test.batch_labels(0, 16);
    let cs = exec.run(&[RtInput::F32(&x), RtInput::I32(y)]).unwrap();
    assert_eq!(cs.shape(), &[16]);
    for v in cs.data() {
        assert!(*v >= 0.0 && v.is_finite(), "CS value {v}");
    }
}

#[test]
fn executions_are_deterministic() {
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let exec = engine.executable("full_fwd_b1").unwrap();
    let x = test.batch(3, 1).unwrap();
    let a = exec.run(&[RtInput::F32(&x)]).unwrap();
    let b = exec.run(&[RtInput::F32(&x)]).unwrap();
    assert_eq!(a.data(), b.data());
}

#[test]
fn wrong_input_shape_is_rejected() {
    let engine = engine();
    let test = engine.dataset("test").unwrap();
    let exec = engine.executable("full_fwd_b16").unwrap();
    let x = test.batch(0, 1).unwrap(); // batch 1 into a b16 artifact
    assert!(exec.run(&[RtInput::F32(&x)]).is_err());
}

#[test]
fn engine_caches_compiled_executables() {
    let engine = engine();
    let a = engine.executable("full_fwd_b1").unwrap();
    let b = engine.executable("full_fwd_b1").unwrap();
    assert!(std::rc::Rc::ptr_eq(&a, &b));
    assert!(engine.cached().contains(&"full_fwd_b1".to_string()));
}

#[test]
fn lite_model_loses_accuracy_vs_base() {
    let engine = engine();
    if !engine.manifest().executables.contains_key("full_fwd_lite_b16")
    {
        return;
    }
    let test = engine.dataset("test").unwrap();
    let base = engine.executable("full_fwd_b16").unwrap();
    let lite = engine.executable("full_fwd_lite_b16").unwrap();
    let mut base_ok = 0;
    let mut lite_ok = 0;
    let n = 128;
    let mut start = 0;
    while start + 16 <= n {
        let x = test.batch(start, 16).unwrap();
        let labels = test.batch_labels(start, 16);
        for (exec, ok) in [(&base, &mut base_ok), (&lite, &mut lite_ok)] {
            let logits = exec.run(&[RtInput::F32(&x)]).unwrap();
            for (p, l) in logits.argmax_last().iter().zip(labels) {
                if *p == *l as usize {
                    *ok += 1;
                }
            }
        }
        start += 16;
    }
    assert!(
        base_ok > lite_ok,
        "lite ({lite_ok}/{n}) should underperform base ({base_ok}/{n})"
    );
}
