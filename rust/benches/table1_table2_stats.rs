//! Bench: regenerate the paper's Table I and Table II (VGG16 statistics)
//! and verify the aggregate numbers match the paper exactly.
//!
//! Paper reference values (Sec. V-D):
//!   Total params               138.357.544
//!   Total mult-adds (G)        247.74        (batch 16)
//!   Forward/backward pass (MB) 1735.26
//!   Estimated total size (MB)  2298.32

use sei::model::{self, model_stats};
use sei::util::bench::Bencher;

fn main() {
    println!("=== Table I / Table II regeneration ===\n");
    let net = model::vgg16_full();
    let table1 = model::render_table1(&net, 16);
    println!("{table1}");
    let table2 = model::render_table2(&net, 16);
    println!("{table2}");

    // Paper-vs-measured assertions (hard: these are pure arithmetic).
    let s = model_stats(&net, 16);
    let checks = [
        ("total params", s.total_params as f64, 138_357_544.0, 0.0),
        ("mult-adds (G)", s.mult_adds_g, 247.74, 0.005),
        ("fwd/bwd (MB)", s.fwd_bwd_mb, 1735.26, 0.01),
        ("total size (MB)", s.total_mb, 2298.32, 0.01),
    ];
    println!("paper-vs-measured:");
    for (name, got, want, tol) in checks {
        let ok = (got - want).abs() <= tol;
        println!(
            "  {name:<16} paper {want:>14.2}  measured {got:>14.2}  {}",
            if ok { "MATCH" } else { "MISMATCH" }
        );
        assert!(ok, "{name}");
    }

    // Also print the slim (trained) model card for reference.
    let slim = model::vgg16_slim(32, 0.125, 64, 10);
    println!("\n(slim trained model: {} params, {:.3} G mult-adds @ b16)",
             slim.total_params(),
             model_stats(&slim, 16).mult_adds_g);

    println!("\n--- generation speed ---");
    let b = Bencher::default();
    b.bench("table1_render", || {
        std::hint::black_box(model::render_table1(&net, 16));
    });
    b.bench("table2_render", || {
        std::hint::black_box(model::render_table2(&net, 16));
    });
    b.bench("model_stats", || {
        std::hint::black_box(model_stats(&net, 16));
    });
}
