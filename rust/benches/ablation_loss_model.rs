//! Ablation: i.i.d. saboteur (the paper's loss model) vs Gilbert-Elliott
//! bursty loss at the same *stationary* loss rate.
//!
//! DESIGN.md calls this out: the paper assumes independent losses; real
//! wireless channels lose packets in bursts. Bursts change the two
//! protocols asymmetrically — TCP amortizes a burst into one recovery
//! episode (cheaper per lost packet), while UDP loses a *contiguous* tensor
//! region (a concentrated hole can hurt accuracy differently from scattered
//! single-float corruption).

use std::path::Path;

use sei::coordinator::{run_scenario, ModelScale, QosRequirements,
                       ScenarioConfig, ScenarioKind};
use sei::model::DeviceProfile;
use sei::netsim::link::LossModel;
use sei::netsim::transfer::{Channel, NetworkConfig, Protocol};
use sei::netsim::Dir;
use sei::report::csv::Csv;
use sei::runtime::{load_backend, InferenceBackend};

const FRAMES: usize = 160;

fn tcp_mean_latency(model: LossModel, loss: f64, bytes: u64) -> f64 {
    let mut total = 0.0;
    let mut n = 0u32;
    for seed in 0..6u64 {
        let mut net = NetworkConfig::gigabit(Protocol::Tcp, loss, 300 + seed);
        net.loss_model = model;
        let mut ch = Channel::new(net);
        for f in 0..60u64 {
            ch.advance_to(f * 50_000_000);
            total += ch.send(Dir::Up, bytes).unwrap().latency_ns() as f64;
            n += 1;
        }
    }
    total / n as f64 / 1e6
}

fn main() {
    println!("=== ablation: i.i.d. vs bursty (Gilbert-Elliott) loss ===\n");
    let mut csv = Csv::new(&["loss", "model", "tcp_latency_ms",
                             "udp_accuracy"]);

    // TCP latency side (paper-scale L11 latent).
    println!("TCP mean latency, 803 kB latent (SC@L11 volumetrics):");
    println!("{:<8} {:>12} {:>14}", "loss", "iid [ms]", "bursty(8) [ms]");
    for loss in [0.0, 0.02, 0.05, 0.08] {
        let iid = tcp_mean_latency(LossModel::Iid, loss, 803_000);
        let ge = tcp_mean_latency(LossModel::bursty(loss, 8.0), loss, 803_000);
        println!("{:<8} {:>12.2} {:>14.2}", format!("{:.0}%", loss * 100.0),
                 iid, ge);
        csv.row(vec![loss.to_string(), "iid-tcp".into(),
                     format!("{iid:.4}"), String::new()]);
        csv.row(vec![loss.to_string(), "bursty-tcp".into(),
                     format!("{ge:.4}"), String::new()]);
    }

    // UDP accuracy side needs a model backend.
    {
        let engine =
            load_backend(Path::new("artifacts")).expect("backend");
        let test = engine.dataset("test").expect("test");
        println!("\nUDP accuracy under corruption (RC scenario, slim):");
        println!("{:<8} {:>10} {:>12}", "loss", "iid", "bursty(8)");
        for loss in [0.0, 0.05, 0.10, 0.20] {
            let mut accs = Vec::new();
            for model in [LossModel::Iid, LossModel::bursty(loss, 8.0)] {
                let mut net =
                    NetworkConfig::gigabit(Protocol::Udp, loss, 555);
                net.loss_model = model;
                let cfg = ScenarioConfig::two_tier(
                    ScenarioKind::Rc,
                    net,
                    DeviceProfile::edge_gpu(),
                    DeviceProfile::server_gpu(),
                    ModelScale::Slim,
                    50_000_000,
                );
                let r = run_scenario(&*engine, &cfg, &test, FRAMES,
                                     &QosRequirements::none())
                    .expect("scenario");
                accs.push(r.accuracy);
            }
            println!("{:<8} {:>9.1}% {:>11.1}%",
                     format!("{:.0}%", loss * 100.0),
                     accs[0] * 100.0, accs[1] * 100.0);
            csv.row(vec![loss.to_string(), "iid-udp".into(), String::new(),
                         format!("{:.4}", accs[0])]);
            csv.row(vec![loss.to_string(), "bursty-udp".into(),
                         String::new(), format!("{:.4}", accs[1])]);
        }
    }
    csv.write(Path::new("reports/ablation_loss_model.csv")).unwrap();
    println!("\nwrote reports/ablation_loss_model.csv");
}
