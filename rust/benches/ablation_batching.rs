//! Ablation: server-side dynamic batching (batch 1 vs 16) on the tail.
//!
//! Uses the b1 and b16 tail executables of the active backend: measures
//! wall time per frame with and without batching, plus the queueing delay the batcher's
//! deadline policy adds under a Poisson arrival stream — the classic
//! throughput-vs-latency trade-off a deployment must tune.

use std::path::Path;

use sei::coordinator::batcher::{BatchPolicy, Batcher};
use sei::coordinator::workload::{ArrivalProcess, Workload};
use sei::runtime::{load_backend, Executable, InferenceBackend, RtInput};
use sei::util::bench::Bencher;

fn main() {
    let engine =
        load_backend(Path::new("artifacts")).expect("backend");
    let test = engine.dataset("test").expect("test");
    let splits = engine.manifest().available_splits();
    let split = *splits.last().expect("splits");

    println!("=== ablation: dynamic batching on the tail (SC@L{split}) ===\n");
    let head16 = engine.executable(&format!("head_L{split}_b16")).unwrap();
    let tail1 = engine.executable(&format!("tail_L{split}_b1")).unwrap();
    let tail16 = engine.executable(&format!("tail_L{split}_b16")).unwrap();

    let x16 = test.batch(0, 16).unwrap();
    let z16 = head16.run(&[RtInput::F32(&x16)]).unwrap();
    let z1 = z16.slice_rows(0, 1).unwrap();

    let b = Bencher::default();
    let s1 = b.bench("tail_b1 execute (1 frame)", || {
        std::hint::black_box(tail1.run(&[RtInput::F32(&z1)]).unwrap());
    });
    let s16 = b.bench("tail_b16 execute (16 frames)", || {
        std::hint::black_box(tail16.run(&[RtInput::F32(&z16)]).unwrap());
    });
    let per_frame_b1 = s1.mean_ns;
    let per_frame_b16 = s16.mean_ns / 16.0;
    println!(
        "\nper-frame PJRT cost: b1 {:.0} µs vs b16 {:.1} µs  \
         (batching speedup {:.2}x)",
        per_frame_b1 / 1e3,
        per_frame_b16 / 1e3,
        per_frame_b1 / per_frame_b16
    );

    // Queueing delay the deadline policy adds under Poisson arrivals.
    println!("\nqueueing delay under Poisson arrivals (simulated):");
    println!("{:<24} {:>12} {:>14} {:>12}", "policy", "mean batch",
             "mean wait [ms]", "batches");
    for (name, policy, fps) in [
        ("immediate @200fps", BatchPolicy::immediate(), 200.0),
        ("b16/5ms @200fps", BatchPolicy::new(16, 5_000_000), 200.0),
        ("b16/5ms @2000fps", BatchPolicy::new(16, 5_000_000), 2000.0),
        ("b16/20ms @200fps", BatchPolicy::new(16, 20_000_000), 200.0),
    ] {
        let mut batcher = Batcher::new(policy);
        let mut wl = Workload::new(ArrivalProcess::Poisson { fps }, 9);
        let mut waits = Vec::new();
        let mut sizes = Vec::new();
        for _ in 0..4000 {
            let t = wl.next_arrival();
            if let Some(d) = batcher.deadline() {
                if d <= t {
                    if let Some(batch) = batcher.poll(d) {
                        waits.push(batch.mean_wait_ns());
                        sizes.push(batch.len());
                    }
                }
            }
            if let Some(batch) = batcher.offer(t) {
                waits.push(batch.mean_wait_ns());
                sizes.push(batch.len());
            }
        }
        let mean_wait =
            waits.iter().sum::<f64>() / waits.len().max(1) as f64 / 1e6;
        let mean_size =
            sizes.iter().sum::<usize>() as f64 / sizes.len().max(1) as f64;
        println!("{:<24} {:>12.2} {:>14.3} {:>12}", name, mean_size,
                 mean_wait, sizes.len());
    }
    println!(
        "\ntakeaway: batching pays {:.2}x backend throughput for a bounded \
         (max_wait) queueing delay — worth it once arrival rate saturates \
         the b1 path.",
        per_frame_b1 / per_frame_b16
    );
}
