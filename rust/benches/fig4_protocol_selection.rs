//! Bench: regenerate the paper's Fig. 4 — RC-scenario accuracy (left) and
//! latency (right) vs packet loss rate under TCP and UDP, 1 Gb/s FD.
//!
//! Accuracy is *measured*: every frame's input tensor is transferred
//! through the simulated channel and — under UDP — corrupted exactly where
//! datagrams were lost, then classified by the active backend's model.
//! Latency uses paper-scale volumetrics (224x224x3 f32 input ≈ 602 kB).
//! Expected shape: TCP accuracy flat / latency rising; UDP latency flat /
//! accuracy falling. Writes reports/fig4.txt and reports/fig4.csv.

use std::path::Path;

use sei::coordinator::{run_scenario, simulate_latency, ModelScale,
                       QosRequirements, ScenarioConfig, ScenarioKind};
use sei::model::DeviceProfile;
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::report::csv::Csv;
use sei::report::fig4_report;
use sei::runtime::{load_backend, InferenceBackend};

const ACC_FRAMES: usize = 192;
const LAT_FRAMES: usize = 300;

fn main() {
    let engine =
        load_backend(Path::new("artifacts")).expect("backend");
    let test = engine.dataset("test").expect("test");
    let loss_rates = vec![0.0, 0.01, 0.02, 0.04, 0.06, 0.08, 0.10];
    let qos = QosRequirements::none();

    println!("=== Fig. 4: protocol selection (RC, 1 Gb/s FD) ===");
    println!(
        "accuracy: {ACC_FRAMES} real inferences/point; latency: paper-scale \
         volumetrics, {LAT_FRAMES} frames/point\n"
    );

    let t0 = std::time::Instant::now();
    let mut acc = vec![Vec::new(), Vec::new()]; // [tcp, udp]
    let mut lat = vec![Vec::new(), Vec::new()];
    for (pi, proto) in [Protocol::Tcp, Protocol::Udp].iter().enumerate() {
        for &loss in &loss_rates {
            // Accuracy at slim scale with real inference + corruption.
            let cfg_acc = ScenarioConfig {
                kind: ScenarioKind::Rc,
                net: NetworkConfig::gigabit(*proto, loss, 4242),
                edge: DeviceProfile::edge_gpu(),
                server: DeviceProfile::server_gpu(),
                scale: ModelScale::Slim,
                frame_period_ns: 50_000_000,
            };
            let r = run_scenario(&*engine, &cfg_acc, &test, ACC_FRAMES,
                                 &qos)
                .expect("scenario");
            acc[pi].push(r.accuracy);
            // Latency at paper scale (VGG16@224 input volume).
            let cfg_lat = ScenarioConfig {
                scale: ModelScale::Vgg16Full,
                net: NetworkConfig::gigabit(*proto, loss, 777),
                ..cfg_acc
            };
            let lats = simulate_latency(&*engine, &cfg_lat, LAT_FRAMES)
                .expect("lat");
            lat[pi].push(
                lats.iter().map(|v| *v as f64).sum::<f64>()
                    / lats.len() as f64
                    / 1e9,
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let report =
        fig4_report(&loss_rates, &acc[0], &acc[1], &lat[0], &lat[1]);
    println!("{report}");

    // Shape acceptance.
    let tcp_acc_flat = acc[0]
        .iter()
        .all(|&a| (a - acc[0][0]).abs() < 0.02);
    let udp_acc_drops = acc[1].last().unwrap() < &(acc[1][0] - 0.05);
    let tcp_lat_grows =
        lat[0].last().unwrap() > &(lat[0][0] * 1.5);
    let udp_lat_flat = lat[1]
        .iter()
        .all(|&l| (l - lat[1][0]).abs() / lat[1][0] < 0.02);
    println!("shape checks (paper Sec. V-C):");
    println!("  TCP accuracy loss-independent: {tcp_acc_flat}");
    println!("  UDP accuracy decays with loss: {udp_acc_drops}");
    println!("  TCP latency grows with loss:   {tcp_lat_grows}");
    println!("  UDP latency loss-independent:  {udp_lat_flat}");

    let mut csv = Csv::new(&["loss", "tcp_accuracy", "udp_accuracy",
                             "tcp_latency_s", "udp_latency_s"]);
    for (i, &l) in loss_rates.iter().enumerate() {
        csv.row(vec![
            format!("{l}"),
            format!("{:.4}", acc[0][i]),
            format!("{:.4}", acc[1][i]),
            format!("{:.6}", lat[0][i]),
            format!("{:.6}", lat[1][i]),
        ]);
    }
    csv.write(Path::new("reports/fig4.csv")).unwrap();
    std::fs::write("reports/fig4.txt", &report).unwrap();
    println!(
        "\nwrote reports/fig4.csv, reports/fig4.txt in {wall:.1}s \
         ({} real inferences)",
        2 * loss_rates.len() * ACC_FRAMES
    );
}
