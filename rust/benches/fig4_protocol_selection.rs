//! Bench: regenerate the paper's Fig. 4 — RC-scenario accuracy (left) and
//! latency (right) vs packet loss rate under TCP and UDP, 1 Gb/s FD.
//!
//! Both panels run on the design-space sweep engine (`coordinator::sweep`)
//! as two grids over protocol × loss: a full-mode grid measuring accuracy
//! (every frame's input tensor is transferred through the simulated
//! channel and — under UDP — corrupted exactly where datagrams were lost,
//! then classified by the active backend's model) and a latency-only grid
//! at paper-scale volumetrics (224x224x3 f32 input ≈ 602 kB). Expected
//! shape: TCP accuracy flat / latency rising; UDP latency flat / accuracy
//! falling. Writes reports/fig4.txt and reports/fig4.csv.

use std::path::Path;

use sei::coordinator::{
    run_sweep, ModelScale, ScenarioKind, SweepMode, SweepSpec,
};
use sei::netsim::transfer::Protocol;
use sei::report::csv::Csv;
use sei::report::fig4_report;
use sei::runtime::load_backend_for;

const ACC_FRAMES: usize = 192;
const LAT_FRAMES: usize = 300;

fn main() {
    let loss_rates = vec![0.0, 0.01, 0.02, 0.04, 0.06, 0.08, 0.10];

    // Accuracy at slim scale with real inference + corruption.
    let mut acc_spec = SweepSpec::new("fig4_accuracy");
    acc_spec.scenarios = vec![ScenarioKind::Rc];
    acc_spec.protocols = vec![Protocol::Tcp, Protocol::Udp];
    acc_spec.loss_rates = loss_rates.clone();
    acc_spec.scales = vec![ModelScale::Slim];
    acc_spec.frames = ACC_FRAMES;
    acc_spec.seed = 4242;
    acc_spec.frame_period_ns = 50_000_000;

    // Latency at paper scale (VGG16@224 input volume), no model execution.
    let mut lat_spec = acc_spec.clone();
    lat_spec.name = "fig4_latency".to_string();
    lat_spec.mode = SweepMode::LatencyOnly;
    lat_spec.scales = vec![ModelScale::Full];
    lat_spec.frames = LAT_FRAMES;
    lat_spec.seed = 777;

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!("=== Fig. 4: protocol selection (RC, 1 Gb/s FD) ===");
    println!(
        "accuracy: {ACC_FRAMES} real inferences/point; latency: paper-scale \
         volumetrics, {LAT_FRAMES} frames/point; sweep engine on {threads} \
         thread(s)\n"
    );

    let factory =
        |arch| load_backend_for(Path::new("artifacts"), arch);
    let t0 = std::time::Instant::now();
    let acc_sweep = run_sweep(&acc_spec, threads, &factory).expect("sweep");
    let lat_sweep = run_sweep(&lat_spec, threads, &factory).expect("sweep");
    let wall = t0.elapsed().as_secs_f64();

    let n_loss = loss_rates.len();
    let mut acc = vec![Vec::new(), Vec::new()]; // [tcp, udp]
    let mut lat = vec![Vec::new(), Vec::new()];
    for (pi, proto) in [Protocol::Tcp, Protocol::Udp].iter().enumerate() {
        for (li, &loss) in loss_rates.iter().enumerate() {
            let pa = &acc_sweep.points[pi * n_loss + li];
            assert_eq!(pa.protocol, *proto);
            assert!((pa.loss - loss).abs() < 1e-12);
            acc[pi].push(pa.accuracy.expect("full-mode point"));
            let pl = &lat_sweep.points[pi * n_loss + li];
            assert_eq!(pl.protocol, *proto);
            assert!((pl.loss - loss).abs() < 1e-12);
            lat[pi].push(pl.mean_latency_ns / 1e9);
        }
    }

    let report =
        fig4_report(&loss_rates, &acc[0], &acc[1], &lat[0], &lat[1]);
    println!("{report}");

    // Shape acceptance.
    let tcp_acc_flat = acc[0]
        .iter()
        .all(|&a| (a - acc[0][0]).abs() < 0.02);
    let udp_acc_drops = acc[1].last().unwrap() < &(acc[1][0] - 0.05);
    let tcp_lat_grows =
        lat[0].last().unwrap() > &(lat[0][0] * 1.5);
    let udp_lat_flat = lat[1]
        .iter()
        .all(|&l| (l - lat[1][0]).abs() / lat[1][0] < 0.02);
    println!("shape checks (paper Sec. V-C):");
    println!("  TCP accuracy loss-independent: {tcp_acc_flat}");
    println!("  UDP accuracy decays with loss: {udp_acc_drops}");
    println!("  TCP latency grows with loss:   {tcp_lat_grows}");
    println!("  UDP latency loss-independent:  {udp_lat_flat}");

    let mut csv = Csv::new(&["loss", "tcp_accuracy", "udp_accuracy",
                             "tcp_latency_s", "udp_latency_s"]);
    for (i, &l) in loss_rates.iter().enumerate() {
        csv.row(vec![
            format!("{l}"),
            format!("{:.4}", acc[0][i]),
            format!("{:.4}", acc[1][i]),
            format!("{:.6}", lat[0][i]),
            format!("{:.6}", lat[1][i]),
        ]);
    }
    csv.write(Path::new("reports/fig4.csv")).unwrap();
    std::fs::write("reports/fig4.txt", &report).unwrap();
    println!(
        "\nwrote reports/fig4.csv, reports/fig4.txt in {wall:.1}s \
         ({} real inferences)",
        2 * loss_rates.len() * ACC_FRAMES
    );
}
