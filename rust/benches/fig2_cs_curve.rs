//! Bench: regenerate the paper's Fig. 2 — the Cumulative Saliency curve
//! overlaid with the per-layer split accuracy, with candidate split points
//! at the CS local maxima.
//!
//! The CS curve is recomputed *in Rust* by executing the per-layer
//! Grad-CAM executables on the active inference backend (PJRT artifacts
//! under the `xla` feature, the hermetic analytic backend otherwise); the
//! split-accuracy trace comes from the manifest. Writes reports/fig2.txt
//! and reports/fig2.csv.

use std::path::Path;

use sei::coordinator::saliency::compute_cs_curve;
use sei::report::csv::Csv;
use sei::report::fig2_report;
use sei::runtime::{load_backend, Executable, InferenceBackend};
use sei::util::bench::Bencher;

fn main() {
    let engine =
        load_backend(Path::new("artifacts")).expect("backend");
    let test = engine.dataset("test").expect("test set");
    let names = engine.manifest().model.layer_names.clone();

    println!("=== Fig. 2: CS curve + split accuracy ===\n");
    let n_images = if engine.manifest().fast { 32 } else { 128 };
    let t0 = std::time::Instant::now();
    let curve = compute_cs_curve(&*engine, &test, n_images).expect("cs");
    let cs_seconds = t0.elapsed().as_secs_f64();
    let norm = curve.normalized();

    let mut rows = Vec::new();
    let mut csv = Csv::new(&["layer", "name", "is_pool", "cs_norm",
                             "split_accuracy"]);
    for (i, &li) in curve.layers.iter().enumerate() {
        let name = names[li].clone();
        let is_pool = name.ends_with("_pool");
        let acc = engine
            .manifest()
            .split_eval_for(li)
            .map(|r| r.accuracy)
            .unwrap_or(f64::NAN);
        csv.row(vec![
            li.to_string(),
            name.clone(),
            is_pool.to_string(),
            format!("{:.6}", norm[i]),
            if acc.is_nan() { String::new() } else { format!("{acc:.4}") },
        ]);
        rows.push((li, name, is_pool, norm[i], acc));
    }
    println!("{}", fig2_report(&rows));

    let candidates = curve.candidates(2);
    println!("candidate split points (CS local maxima): {candidates:?}");
    println!(
        "paper's VGG16 candidates for reference: [5, 9, 11, 13, 15] \
         (block2_pool, block3_pool, block4_conv2, block4_pool, block5_conv2)"
    );
    // Shape acceptance: candidates must include pool layers and/or
    // late-block convs — the paper's qualitative claim.
    let pools = candidates
        .iter()
        .filter(|&&c| names[c].ends_with("_pool"))
        .count();
    println!(
        "shape check: {pools}/{} candidates are pooling layers",
        candidates.len()
    );

    // Correlation between CS and split accuracy (the curve's whole point).
    let pairs: Vec<(f64, f64)> = rows
        .iter()
        .filter(|r| !r.4.is_nan())
        .map(|r| (r.3, r.4))
        .collect();
    if pairs.len() > 2 {
        let n = pairs.len() as f64;
        let mx = pairs.iter().map(|p| p.0).sum::<f64>() / n;
        let my = pairs.iter().map(|p| p.1).sum::<f64>() / n;
        let cov: f64 =
            pairs.iter().map(|p| (p.0 - mx) * (p.1 - my)).sum::<f64>();
        let vx: f64 = pairs.iter().map(|p| (p.0 - mx).powi(2)).sum::<f64>();
        let vy: f64 = pairs.iter().map(|p| (p.1 - my).powi(2)).sum::<f64>();
        let r = cov / (vx.sqrt() * vy.sqrt() + 1e-12);
        println!("pearson(CS, split accuracy) = {r:.3}");
    }

    csv.write(Path::new("reports/fig2.csv")).unwrap();
    std::fs::create_dir_all("reports").unwrap();
    std::fs::write("reports/fig2.txt", fig2_report(&rows)).unwrap();
    println!("\nwrote reports/fig2.csv, reports/fig2.txt");
    println!(
        "CS computation: {} layers x {n_images} images in {cs_seconds:.1}s \
         (pure Rust, {} backend)",
        curve.layers.len(),
        engine.name()
    );

    // Timing: one gradcam artifact execution (the design-phase hot loop).
    if let Some(&li) = curve.layers.first() {
        let exec = engine
            .executable(&format!("gradcam_L{li}_b16"))
            .expect("gradcam exec");
        let x = test.batch(0, 16).unwrap();
        let y: Vec<i32> = test.batch_labels(0, 16).to_vec();
        let b = Bencher::quick();
        b.bench(&format!("gradcam_L{li}_b16 execute"), || {
            use sei::runtime::RtInput;
            std::hint::black_box(
                exec.run(&[RtInput::F32(&x), RtInput::I32(&y)]).unwrap(),
            );
        });
    }
}
