//! Bench: regenerate the paper's Fig. 3 — SC frame latency vs packet loss
//! rate for splits at layer 11 (block4_conv2) and layer 15 (block5_conv2),
//! TCP over the 1 Gb/s full-duplex channel, against the ICE-Lab 0.05 s
//! (20 FPS) constraint.
//!
//! The grid (2 splits × 9 loss rates × 5 seeds) runs on the design-space
//! sweep engine (`coordinator::sweep`) across all available cores; results
//! are keyed by grid index, so the output is identical at any thread count.
//!
//! Volumetrics and compute are paper-scale (VGG16 @ 224x224): the L11
//! latent is 256x28x28 f32 ≈ 803 kB/frame, the L15 latent 256x14x14
//! ≈ 201 kB/frame. Expected shape (paper Sec. V-B): L15 satisfies the
//! constraint at every loss rate; L11 violates it beyond a few percent.
//! Writes reports/fig3.txt and reports/fig3.csv.

use std::path::Path;

use sei::coordinator::{
    run_sweep, ModelScale, ScenarioKind, SweepMode, SweepSpec,
};
use sei::netsim::transfer::Protocol;
use sei::report::csv::Csv;
use sei::report::fig3_report;
use sei::runtime::load_backend;

const CONSTRAINT_S: f64 = 0.05; // 20 FPS conveyor belt
const FRAMES: usize = 400;
const SEEDS: usize = 5;

fn main() {
    let loss_rates: Vec<f64> =
        vec![0.0, 0.01, 0.02, 0.03, 0.04, 0.05, 0.06, 0.08, 0.10];
    let splits = [11usize, 15];

    let mut spec = SweepSpec::new("fig3_split_selection");
    spec.mode = SweepMode::LatencyOnly;
    spec.scenarios = splits
        .iter()
        .map(|&split| ScenarioKind::Sc { split })
        .collect();
    spec.protocols = vec![Protocol::Tcp];
    spec.loss_rates = loss_rates.clone();
    spec.scales = vec![ModelScale::Vgg16Full];
    spec.frames = FRAMES;
    spec.seeds_per_point = SEEDS;
    spec.seed = 1000;
    spec.frame_period_ns = 50_000_000;
    spec.max_latency_ms = CONSTRAINT_S * 1e3;

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!("=== Fig. 3: split-point selection under packet loss ===");
    println!(
        "channel: 1 Gb/s full-duplex TCP, 100 µs; constraint {CONSTRAINT_S} s \
         (20 FPS); {FRAMES} frames x {SEEDS} seeds per point; \
         sweep engine on {threads} thread(s)\n"
    );

    let t0 = std::time::Instant::now();
    let sweep = run_sweep(&spec, threads, &|| {
        load_backend(Path::new("artifacts"))
    })
    .expect("sweep");
    let wall = t0.elapsed().as_secs_f64();

    let n_loss = loss_rates.len();
    let mut series = Vec::new();
    let mut csv = Csv::new(&["loss", "split", "mean_latency_s",
                             "p95_latency_s", "violation_rate"]);
    for (si, &split) in splits.iter().enumerate() {
        let mut means = Vec::new();
        for (li, &loss) in loss_rates.iter().enumerate() {
            let p = &sweep.points[si * n_loss + li];
            assert_eq!(p.kind, ScenarioKind::Sc { split });
            assert!((p.loss - loss).abs() < 1e-12);
            let mean = p.mean_latency_ns / 1e9;
            let p95 = p.p95_latency_ns as f64 / 1e9;
            let viol = 1.0 - p.deadline_hit_rate.unwrap_or(1.0);
            csv.row(vec![
                format!("{loss}"),
                format!("L{split}"),
                format!("{mean:.6}"),
                format!("{p95:.6}"),
                format!("{viol:.4}"),
            ]);
            means.push(mean);
        }
        series.push((format!("SC@L{split}"), means));
    }

    let report = fig3_report(&loss_rates, &series, CONSTRAINT_S);
    println!("{report}");

    // Shape acceptance (who wins, where the crossover falls).
    let l11 = &series[0].1;
    let l15 = &series[1].1;
    let ok15 = l15.iter().all(|&v| v <= CONSTRAINT_S);
    let crossover = loss_rates
        .iter()
        .zip(l11)
        .find(|(_, &v)| v > CONSTRAINT_S)
        .map(|(l, _)| *l);
    println!("shape checks:");
    println!(
        "  L15 within constraint at every loss rate: {}",
        if ok15 { "YES (paper: yes)" } else { "NO (paper: yes)" }
    );
    match crossover {
        Some(l) => println!(
            "  L11 first violates at loss = {:.0}% (paper: >3%)",
            l * 100.0
        ),
        None => println!("  L11 never violates (paper: violates >3%)"),
    }
    println!(
        "  L11 latency > L15 latency at max loss: {}",
        l11.last().unwrap() > l15.last().unwrap()
    );

    csv.write(Path::new("reports/fig3.csv")).unwrap();
    std::fs::write("reports/fig3.txt", &report).unwrap();
    let points = loss_rates.len() * splits.len();
    println!(
        "\nwrote reports/fig3.csv, reports/fig3.txt — {points} points x \
         {FRAMES} frames x {SEEDS} seeds in {wall:.1}s on {threads} \
         thread(s) ({:.0} simulated frames/s)",
        (points * FRAMES * SEEDS) as f64 / wall
    );
}
