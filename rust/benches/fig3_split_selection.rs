//! Bench: regenerate the paper's Fig. 3 — SC frame latency vs packet loss
//! rate for splits at layer 11 (block4_conv2) and layer 15 (block5_conv2),
//! TCP over the 1 Gb/s full-duplex channel, against the ICE-Lab 0.05 s
//! (20 FPS) constraint.
//!
//! The grid (2 splits × 9 loss rates × 5 seeds) runs on the design-space
//! sweep engine (`coordinator::sweep`) across all available cores; results
//! are keyed by grid index, so the output is identical at any thread count.
//!
//! Volumetrics and compute are paper-scale (VGG16 @ 224x224): the L11
//! latent is 256x28x28 f32 ≈ 803 kB/frame, the L15 latent 256x14x14
//! ≈ 201 kB/frame. Expected shape (paper Sec. V-B): L15 satisfies the
//! constraint at every loss rate; L11 violates it beyond a few percent.
//! Writes reports/fig3.txt and reports/fig3.csv.
//!
//! A second, smaller grid sweeps the **architecture axis** (VGG16,
//! ResNet-18, MobileNetV2 at the shared cut id 5, paper scale) and — when
//! `SEI_BENCH_JSON` is set — merges the per-arch rows into that file
//! (e.g. CI's `BENCH_netsim.json`) under the `fig3_arch` key, so the perf
//! trajectory tracks all three architectures. `SEI_BENCH_QUICK=1` shrinks
//! frames/seeds for the CI smoke.

use std::path::Path;

use sei::coordinator::{
    run_sweep, ModelScale, ScenarioKind, SweepMode, SweepSpec,
};
use sei::model::Arch;
use sei::netsim::transfer::Protocol;
use sei::report::csv::Csv;
use sei::report::fig3_report;
use sei::runtime::load_backend_for;
use sei::util::json::{self, Json};

const CONSTRAINT_S: f64 = 0.05; // 20 FPS conveyor belt

fn main() {
    let quick = std::env::var("SEI_BENCH_QUICK").is_ok();
    let frames: usize = if quick { 60 } else { 400 };
    let seeds: usize = if quick { 2 } else { 5 };
    let loss_rates: Vec<f64> = if quick {
        vec![0.0, 0.02, 0.05, 0.10]
    } else {
        vec![0.0, 0.01, 0.02, 0.03, 0.04, 0.05, 0.06, 0.08, 0.10]
    };
    let splits = [11usize, 15];

    let mut spec = SweepSpec::new("fig3_split_selection");
    spec.mode = SweepMode::LatencyOnly;
    spec.scenarios = splits
        .iter()
        .map(|&split| ScenarioKind::Sc { split })
        .collect();
    spec.protocols = vec![Protocol::Tcp];
    spec.loss_rates = loss_rates.clone();
    spec.scales = vec![ModelScale::Full];
    spec.frames = frames;
    spec.seeds_per_point = seeds;
    spec.seed = 1000;
    spec.frame_period_ns = 50_000_000;
    spec.max_latency_ms = CONSTRAINT_S * 1e3;

    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    println!("=== Fig. 3: split-point selection under packet loss ===");
    println!(
        "channel: 1 Gb/s full-duplex TCP, 100 µs; constraint {CONSTRAINT_S} s \
         (20 FPS); {frames} frames x {seeds} seeds per point; \
         sweep engine on {threads} thread(s)\n"
    );

    let t0 = std::time::Instant::now();
    let sweep = run_sweep(&spec, threads, &|arch| {
        load_backend_for(Path::new("artifacts"), arch)
    })
    .expect("sweep");
    let wall = t0.elapsed().as_secs_f64();

    let n_loss = loss_rates.len();
    let mut series = Vec::new();
    let mut csv = Csv::new(&["loss", "split", "mean_latency_s",
                             "p95_latency_s", "violation_rate"]);
    for (si, &split) in splits.iter().enumerate() {
        let mut means = Vec::new();
        for (li, &loss) in loss_rates.iter().enumerate() {
            let p = &sweep.points[si * n_loss + li];
            assert_eq!(p.kind, ScenarioKind::Sc { split });
            assert!((p.loss - loss).abs() < 1e-12);
            let mean = p.mean_latency_ns / 1e9;
            let p95 = p.p95_latency_ns as f64 / 1e9;
            let viol = 1.0 - p.deadline_hit_rate.unwrap_or(1.0);
            csv.row(vec![
                format!("{loss}"),
                format!("L{split}"),
                format!("{mean:.6}"),
                format!("{p95:.6}"),
                format!("{viol:.4}"),
            ]);
            means.push(mean);
        }
        series.push((format!("SC@L{split}"), means));
    }

    let report = fig3_report(&loss_rates, &series, CONSTRAINT_S);
    println!("{report}");

    // Shape acceptance (who wins, where the crossover falls).
    let l11 = &series[0].1;
    let l15 = &series[1].1;
    let ok15 = l15.iter().all(|&v| v <= CONSTRAINT_S);
    let crossover = loss_rates
        .iter()
        .zip(l11)
        .find(|(_, &v)| v > CONSTRAINT_S)
        .map(|(l, _)| *l);
    println!("shape checks:");
    println!(
        "  L15 within constraint at every loss rate: {}",
        if ok15 { "YES (paper: yes)" } else { "NO (paper: yes)" }
    );
    match crossover {
        Some(l) => println!(
            "  L11 first violates at loss = {:.0}% (paper: >3%)",
            l * 100.0
        ),
        None => println!("  L11 never violates (paper: violates >3%)"),
    }
    println!(
        "  L11 latency > L15 latency at max loss: {}",
        l11.last().unwrap() > l15.last().unwrap()
    );

    csv.write(Path::new("reports/fig3.csv")).unwrap();
    std::fs::write("reports/fig3.txt", &report).unwrap();
    let points = loss_rates.len() * splits.len();
    println!(
        "\nwrote reports/fig3.csv, reports/fig3.txt — {points} points x \
         {frames} frames x {seeds} seeds in {wall:.1}s on {threads} \
         thread(s) ({:.0} simulated frames/s)",
        (points * frames * seeds) as f64 / wall
    );

    // -- architecture axis: the same split-selection question across the
    //    zoo, at the shared cut id 5, paper-scale volumetrics. ------------
    let mut arch_spec = SweepSpec::new("fig3_arch_axis");
    arch_spec.mode = SweepMode::LatencyOnly;
    arch_spec.scenarios = vec![ScenarioKind::Sc { split: 5 }];
    arch_spec.protocols = vec![Protocol::Tcp];
    arch_spec.loss_rates = vec![0.0, 0.05];
    arch_spec.scales = vec![ModelScale::Full];
    arch_spec.archs =
        vec![Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2];
    arch_spec.frames = frames.min(120);
    arch_spec.seeds_per_point = seeds.min(2);
    arch_spec.seed = 1000;
    arch_spec.frame_period_ns = 50_000_000;
    arch_spec.max_latency_ms = CONSTRAINT_S * 1e3;
    let arch_sweep = run_sweep(&arch_spec, threads, &|arch| {
        load_backend_for(Path::new("artifacts"), arch)
    })
    .expect("arch sweep");
    println!("\nper-arch SC@5 latency (paper scale, TCP):");
    let mut arch_rows = Vec::new();
    for p in &arch_sweep.points {
        println!(
            "  {:<12} loss {:>4.1}%  mean {:>8.2} ms  p95 {:>8.2} ms",
            p.arch.as_str(),
            p.loss * 100.0,
            p.mean_latency_ns / 1e6,
            p.p95_latency_ns as f64 / 1e6,
        );
        arch_rows.push(json::obj(vec![
            ("arch", json::s(p.arch.as_str())),
            ("split", json::num(5.0)),
            ("loss", json::num(p.loss)),
            ("mean_latency_ms", json::num(p.mean_latency_ns / 1e6)),
            (
                "p95_latency_ms",
                json::num(p.p95_latency_ns as f64 / 1e6),
            ),
            (
                "deadline_hit_rate",
                p.deadline_hit_rate.map(json::num).unwrap_or(Json::Null),
            ),
        ]));
    }

    // -- multi-tier axis: the same question with the head pushed onto a
    //    sensor NPU — SC@11 two-tier vs MC chains ending at the same cut
    //    over sensor -> edge -> cloud, paper scale. -----------------------
    let mut mc_spec = SweepSpec::new("fig3_multi_tier");
    mc_spec.mode = SweepMode::LatencyOnly;
    mc_spec.scenarios = vec![ScenarioKind::Sc { split: 11 }];
    mc_spec.cut_chains = vec![vec![5, 11], vec![9, 11]];
    mc_spec.tiers = vec![
        vec!["edge-gpu".into(), "server-gpu".into()],
        vec![
            "sensor-npu".into(),
            "edge-gpu".into(),
            "server-gpu".into(),
        ],
    ];
    mc_spec.protocols = vec![Protocol::Tcp];
    mc_spec.loss_rates = vec![0.0, 0.05];
    mc_spec.scales = vec![ModelScale::Full];
    mc_spec.frames = frames.min(120);
    mc_spec.seeds_per_point = seeds.min(2);
    mc_spec.seed = 1000;
    mc_spec.frame_period_ns = 50_000_000;
    mc_spec.max_latency_ms = CONSTRAINT_S * 1e3;
    let mc_sweep = run_sweep(&mc_spec, threads, &|arch| {
        load_backend_for(Path::new("artifacts"), arch)
    })
    .expect("multi-tier sweep");
    println!("\nmulti-tier placement at cut 11 (paper scale, TCP):");
    let mut mc_rows = Vec::new();
    for p in &mc_sweep.points {
        println!(
            "  {:<10} over {:<38} loss {:>4.1}%  mean {:>8.2} ms  \
             p95 {:>8.2} ms",
            p.kind.to_string(),
            p.tiers.join(">"),
            p.loss * 100.0,
            p.mean_latency_ns / 1e6,
            p.p95_latency_ns as f64 / 1e6,
        );
        mc_rows.push(json::obj(vec![
            ("scenario", json::s(&p.kind.to_string())),
            ("tiers", json::s(&p.tiers.join(">"))),
            ("loss", json::num(p.loss)),
            ("mean_latency_ms", json::num(p.mean_latency_ns / 1e6)),
            (
                "p95_latency_ms",
                json::num(p.p95_latency_ns as f64 / 1e6),
            ),
            (
                "deadline_hit_rate",
                p.deadline_hit_rate.map(json::num).unwrap_or(Json::Null),
            ),
        ]));
    }

    // Merge the per-arch rows into the shared perf-trajectory file (CI
    // points SEI_BENCH_JSON at BENCH_netsim.json, which netsim_micro has
    // already written — read-modify-write keeps its sections). A file
    // that exists but does not parse as a JSON object is left untouched:
    // clobbering the whole trajectory on a parse error would silently
    // lose every other bench's sections.
    if let Ok(path) = std::env::var("SEI_BENCH_JSON") {
        let mut doc = match std::fs::read_to_string(&path) {
            Ok(text) => match Json::parse(&text) {
                Ok(doc @ Json::Obj(_)) => doc,
                _ => {
                    eprintln!(
                        "SEI_BENCH_JSON {path}: not a JSON object — \
                         leaving the file untouched"
                    );
                    return;
                }
            },
            Err(_) => json::obj(vec![]), // no file yet: start fresh
        };
        if let Json::Obj(map) = &mut doc {
            map.insert("fig3_arch".to_string(), json::arr(arch_rows));
            map.insert("fig3_mc".to_string(), json::arr(mc_rows));
        }
        std::fs::write(&path, doc.to_string()).unwrap();
        println!(
            "\nmerged per-arch + multi-tier rows into {path} \
             (keys: fig3_arch, fig3_mc)"
        );
    }
}
