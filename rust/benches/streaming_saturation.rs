//! Bench: saturation behaviour + engine speed of the closed-loop
//! streaming simulator.
//!
//! Drives a 4-client paper-scale RC deployment through an offered-load
//! ladder, records the achieved throughput / latency / queue depth at
//! each point, and checks the closed-loop contract: past the bottleneck
//! the throughput plateaus while mean and p99 latency grow. Also reports
//! the simulator's own speed (simulated frames per wall-second).
//!
//! Environment knobs (same contract as `netsim_micro`):
//!   SEI_BENCH_QUICK=1      fewer frames per point
//!   SEI_BENCH_JSON=<path>  also write the curve as machine-readable JSON
//!     (CI uploads it as BENCH_streaming.json)

use std::path::Path;
use std::time::Instant;

use sei::coordinator::batcher::BatchPolicy;
use sei::coordinator::{
    run_stream, ModelScale, QosRequirements, ScenarioConfig, ScenarioKind,
    StreamConfig,
};
use sei::model::DeviceProfile;
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::runtime::load_backend;
use sei::util::json::{self, Json};

fn main() {
    let quick = std::env::var("SEI_BENCH_QUICK").is_ok();
    let frames = if quick { 96 } else { 384 };
    let clients = 4usize;
    // Per-client offered rates; aggregate = 4x. The shared 1 Gb/s uplink
    // carries ~602 kB per RC frame (~4.9 ms), so the bottleneck sits
    // around 200 aggregate FPS.
    let ladder: &[f64] = &[10.0, 25.0, 50.0, 100.0, 200.0];

    let engine = load_backend(Path::new("artifacts")).expect("backend");
    let qos = QosRequirements::ice_lab();

    println!(
        "=== streaming_saturation: RC @ VGG16 volumetrics, UDP 1 Gb/s, \
         {clients} clients x {frames} frames{} ===\n",
        if quick { " (quick)" } else { "" }
    );
    println!(
        "{:>14} {:>12} {:>12} {:>12} {:>12} {:>14}",
        "offered (agg)", "achieved", "mean lat", "p99 lat", "max depth",
        "sim frames/s"
    );

    let mut rows: Vec<(f64, f64, f64, f64, usize, f64)> = Vec::new();
    for &fps in ladder {
        let cfg = StreamConfig {
            scenario: ScenarioConfig::two_tier(
                ScenarioKind::Rc,
                NetworkConfig::gigabit(Protocol::Udp, 0.0, 7),
                DeviceProfile::edge_gpu(),
                DeviceProfile::server_gpu(),
                ModelScale::Full,
                (1e9 / fps) as u64,
            ),
            clients,
            frames_per_client: frames,
            batch: BatchPolicy::immediate(),
        };
        let t0 = Instant::now();
        let r = run_stream(&*engine, &cfg, None, &qos).expect("stream");
        let wall = t0.elapsed().as_secs_f64();
        let offered = fps * clients as f64;
        let sim_rate = r.frames as f64 / wall.max(1e-9);
        println!(
            "{:>14.0} {:>12.1} {:>9.2} ms {:>9.2} ms {:>12} {:>14.0}",
            offered,
            r.stats.throughput_fps,
            r.mean_latency_ns / 1e6,
            r.p99_latency_ns as f64 / 1e6,
            r.stats.max_queue_depth,
            sim_rate,
        );
        rows.push((
            offered,
            r.stats.throughput_fps,
            r.mean_latency_ns,
            r.p99_latency_ns as f64,
            r.stats.max_queue_depth,
            wall,
        ));
    }

    // Closed-loop contract: the last two (overloaded) points achieve the
    // same bottleneck throughput, and latency keeps growing with offered
    // load while throughput does not.
    let n = rows.len();
    let (thr_prev, thr_last) = (rows[n - 2].1, rows[n - 1].1);
    let plateau = (thr_last - thr_prev).abs() / thr_prev.max(1e-9) < 0.10;
    let latency_grows = rows[n - 1].2 > 3.0 * rows[0].2
        && rows[n - 1].3 > 3.0 * rows[0].3;
    let thr_capped = thr_last < rows[n - 1].0 * 0.9;
    println!("\nsaturation checks:");
    println!("  throughput plateaus past the bottleneck: {plateau}");
    println!("  mean/p99 latency grow under overload:    {latency_grows}");
    println!("  achieved stays below offered (overload): {thr_capped}");
    assert!(plateau, "throughput must plateau: {thr_prev} vs {thr_last}");
    assert!(latency_grows, "latency must grow under overload");
    assert!(thr_capped, "overloaded throughput must cap at the bottleneck");

    if let Ok(path) = std::env::var("SEI_BENCH_JSON") {
        let entries: Vec<Json> = rows
            .iter()
            .map(|&(offered, thr, mean, p99, depth, wall)| {
                json::obj(vec![
                    ("offered_fps", json::num(offered)),
                    ("throughput_fps", json::num(thr)),
                    ("mean_latency_ns", json::num(mean)),
                    ("p99_latency_ns", json::num(p99)),
                    ("max_queue_depth", json::num(depth as f64)),
                    ("wall_s", json::num(wall)),
                ])
            })
            .collect();
        let doc = json::obj(vec![
            ("bench", json::s("streaming_saturation")),
            ("quick", Json::Bool(quick)),
            ("clients", json::num(clients as f64)),
            ("frames_per_client", json::num(frames as f64)),
            ("curve", json::arr(entries)),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write bench json");
        println!("\nwrote {path}");
    }
}
