//! Bench: saturation behaviour + engine speed of the closed-loop
//! streaming simulator, plus the multi-tenant event-calendar scaling run.
//!
//! Part 1 drives a 4-client paper-scale RC deployment through an
//! offered-load ladder, records the achieved throughput / latency /
//! queue depth at each point, and checks the closed-loop contract: past
//! the bottleneck the throughput plateaus while mean and p99 latency
//! grow. Also reports the simulator's own speed (simulated frames per
//! wall-second).
//!
//! Part 2 measures the discrete-event core itself: a heterogeneous
//! tenant population (archs × RC/SC placements, slow periodic sources so
//! every pending stream keeps a timer in the event queue) is run once on
//! the indexed event calendar and once on the retained linear-scan
//! backend at 10⁴ streams, asserting the calendar sustains >= 10× the
//! events/second; full mode additionally scales the calendar alone to
//! 10⁵ streams. The events/second figures land in the JSON document that
//! CI gates against `benches/baselines/streaming_events.json`.
//!
//! Part 3 runs the adaptive re-split comparison over the committed
//! degrading trace (`examples/specs/trace_suite.json#degrading`): the
//! deadline hit-rates of the best static cut chain, both adaptive switch
//! policies and the zero-cost oracle land in an `adaptive` block that CI
//! gates against `benches/baselines/adaptive_degrading.json` — the
//! outcomes are deterministic, so a drop means the controller regressed,
//! not that the runner was slow.
//!
//! Environment knobs (same contract as `netsim_micro`):
//!   SEI_BENCH_QUICK=1      fewer frames per point, skip the 10⁵ run
//!   SEI_BENCH_JSON=<path>  also write the results as machine-readable
//!     JSON (CI uploads it as BENCH_streaming.json)

use std::path::Path;
use std::time::Instant;

use sei::coordinator::batcher::BatchPolicy;
use sei::coordinator::{
    run_adaptive_comparison, run_hetero_stream, run_stream, AdaptiveConfig,
    ClientSpec, ControllerConfig, Fairness, ModelScale, MultiStreamConfig,
    QosRequirements, ScenarioConfig, ScenarioKind, StreamConfig,
};
use sei::model::{split_points, Arch, DeviceProfile};
use sei::netsim::trace::parse_trace_arg;
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::netsim::QueueKind;
use sei::runtime::{load_backend, load_backend_for, InferenceBackend};
use sei::util::json::{self, Json};

/// A heterogeneous tenant population: architectures and placements cycle
/// per client, every source is slow-periodic (so between its frames the
/// stream parks exactly one pending Emit timer in the event queue — the
/// regime where the linear next-event scan degenerates to O(streams) per
/// pop) and emits two frames.
fn mixed_clients(n: usize) -> Vec<ClientSpec> {
    let archs = [Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2];
    (0..n)
        .map(|i| {
            let kind = if i % 2 == 0 {
                ScenarioKind::Rc
            } else {
                ScenarioKind::Sc { split: 5 }
            };
            let mut c = ClientSpec::new(kind);
            c.arch = archs[i % archs.len()];
            c.scale = ModelScale::Slim;
            // 1 frame per minute per stream: aggregate load stays far
            // below every resource's capacity even at 10⁵ streams, so
            // admission keeps all of them.
            c.frame_period_ns = 60_000_000_000;
            c.frames = 2;
            c.weight = 1 + 3 * (i % 4 == 0) as u64;
            c
        })
        .collect()
}

/// Run `n` mixed tenants on the chosen event-queue backend
/// (latency-only: no model execution) and return
/// (events processed, events per wall-second, admitted streams).
fn hetero_events_run(
    engines: &[(Arch, &dyn InferenceBackend)],
    n: usize,
    queue: QueueKind,
) -> (u64, f64, usize) {
    let cfg = MultiStreamConfig {
        clients: mixed_clients(n),
        hop_nets: vec![NetworkConfig::gigabit(Protocol::Udp, 0.0, 11)],
        tiers: vec![DeviceProfile::edge_gpu(), DeviceProfile::server_gpu()],
        batch: BatchPolicy::immediate(),
        fairness: Fairness::Drr,
        admission: true,
        queue,
    };
    let t0 = Instant::now();
    let report = run_hetero_stream(engines, &cfg, None, &QosRequirements::none())
        .expect("hetero stream");
    let wall = t0.elapsed().as_secs_f64();
    let events = report.aggregate.stats.events_processed;
    (events, events as f64 / wall.max(1e-9), report.admitted())
}

fn main() {
    let quick = std::env::var("SEI_BENCH_QUICK").is_ok();
    let frames = if quick { 96 } else { 384 };
    let clients = 4usize;
    // Per-client offered rates; aggregate = 4x. The shared 1 Gb/s uplink
    // carries ~602 kB per RC frame (~4.9 ms), so the bottleneck sits
    // around 200 aggregate FPS.
    let ladder: &[f64] = &[10.0, 25.0, 50.0, 100.0, 200.0];

    let engine = load_backend(Path::new("artifacts")).expect("backend");
    let qos = QosRequirements::ice_lab();

    println!(
        "=== streaming_saturation: RC @ VGG16 volumetrics, UDP 1 Gb/s, \
         {clients} clients x {frames} frames{} ===\n",
        if quick { " (quick)" } else { "" }
    );
    println!(
        "{:>14} {:>12} {:>12} {:>12} {:>12} {:>14}",
        "offered (agg)", "achieved", "mean lat", "p99 lat", "max depth",
        "sim frames/s"
    );

    let mut rows: Vec<(f64, f64, f64, f64, usize, f64)> = Vec::new();
    for &fps in ladder {
        let cfg = StreamConfig {
            scenario: ScenarioConfig::two_tier(
                ScenarioKind::Rc,
                NetworkConfig::gigabit(Protocol::Udp, 0.0, 7),
                DeviceProfile::edge_gpu(),
                DeviceProfile::server_gpu(),
                ModelScale::Full,
                (1e9 / fps) as u64,
            ),
            clients,
            frames_per_client: frames,
            batch: BatchPolicy::immediate(),
        };
        let t0 = Instant::now();
        let r = run_stream(&*engine, &cfg, None, &qos).expect("stream");
        let wall = t0.elapsed().as_secs_f64();
        let offered = fps * clients as f64;
        let sim_rate = r.frames as f64 / wall.max(1e-9);
        println!(
            "{:>14.0} {:>12.1} {:>9.2} ms {:>9.2} ms {:>12} {:>14.0}",
            offered,
            r.stats.throughput_fps,
            r.mean_latency_ns / 1e6,
            r.p99_latency_ns as f64 / 1e6,
            r.stats.max_queue_depth,
            sim_rate,
        );
        rows.push((
            offered,
            r.stats.throughput_fps,
            r.mean_latency_ns,
            r.p99_latency_ns as f64,
            r.stats.max_queue_depth,
            wall,
        ));
    }

    // Closed-loop contract: the last two (overloaded) points achieve the
    // same bottleneck throughput, and latency keeps growing with offered
    // load while throughput does not.
    let n = rows.len();
    let (thr_prev, thr_last) = (rows[n - 2].1, rows[n - 1].1);
    let plateau = (thr_last - thr_prev).abs() / thr_prev.max(1e-9) < 0.10;
    let latency_grows = rows[n - 1].2 > 3.0 * rows[0].2
        && rows[n - 1].3 > 3.0 * rows[0].3;
    let thr_capped = thr_last < rows[n - 1].0 * 0.9;
    println!("\nsaturation checks:");
    println!("  throughput plateaus past the bottleneck: {plateau}");
    println!("  mean/p99 latency grow under overload:    {latency_grows}");
    println!("  achieved stays below offered (overload): {thr_capped}");
    assert!(plateau, "throughput must plateau: {thr_prev} vs {thr_last}");
    assert!(latency_grows, "latency must grow under overload");
    assert!(thr_capped, "overloaded throughput must cap at the bottleneck");

    // ---- Part 2: event-calendar scaling over heterogeneous tenants ----
    let backends: Vec<(Arch, Box<dyn InferenceBackend>)> =
        [Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2]
            .into_iter()
            .map(|a| {
                (a, load_backend_for(Path::new("artifacts"), a)
                    .expect("backend"))
            })
            .collect();
    let engines: Vec<(Arch, &dyn InferenceBackend)> =
        backends.iter().map(|(a, b)| (*a, &**b)).collect();

    let n_quick = 10_000usize;
    println!(
        "\n=== event calendar vs linear scan @ {n_quick} heterogeneous \
         streams ==="
    );
    let (ev_cal, rate_cal, adm_cal) =
        hetero_events_run(&engines, n_quick, QueueKind::Calendar);
    let (ev_lin, rate_lin, adm_lin) =
        hetero_events_run(&engines, n_quick, QueueKind::LinearScan);
    println!(
        "  calendar    {:>12} events  {:>14.0} events/s  ({adm_cal} \
         admitted)",
        ev_cal, rate_cal
    );
    println!(
        "  linear scan {:>12} events  {:>14.0} events/s  ({adm_lin} \
         admitted)",
        ev_lin, rate_lin
    );
    let speedup = rate_cal / rate_lin.max(1e-9);
    println!("  speedup     {speedup:>12.1}x");
    assert_eq!(adm_cal, n_quick, "all streams must be admitted");
    assert_eq!(
        ev_cal, ev_lin,
        "both backends must process the same event count"
    );
    assert!(
        speedup >= 10.0,
        "calendar must be >= 10x faster than the linear scan at \
         {n_quick} streams, got {speedup:.1}x"
    );

    let full_scale = if quick {
        None
    } else {
        let n_full = 100_000usize;
        println!(
            "\n=== event calendar @ {n_full} heterogeneous streams ==="
        );
        let (ev, rate, adm) =
            hetero_events_run(&engines, n_full, QueueKind::Calendar);
        println!(
            "  calendar    {:>12} events  {:>14.0} events/s  ({adm} \
             admitted)",
            ev, rate
        );
        assert_eq!(adm, n_full, "all streams must be admitted");
        Some((n_full, ev, rate))
    };

    // ---- Part 3: adaptive re-splitting over the committed trace ----
    // Same calibration as tests/trace_semantics.rs: the degrading entry's
    // rates are derived from VGG16's own latent volumetrics, the edge is
    // tuned so the deep low-latent cut runs at 1.02x the frame period.
    let period: u64 = 10_000_000;
    let ad_frames = 60usize;
    let points = split_points(&Arch::Vgg16.full_network());
    let n_cand = points.len() - 1;
    let min_bytes =
        (0..n_cand).map(|i| points[i].latent_bytes()).min().unwrap();
    let d = (0..n_cand)
        .find(|&i| points[i].latent_bytes() == min_bytes)
        .unwrap();
    let (head_d, _) = points[d].split_compute();
    let overhead = 10_000u64;
    let macs =
        head_d as f64 / ((1.02 * period as f64 - overhead as f64) / 1e9);
    let traces = parse_trace_arg(&format!(
        "{}/../examples/specs/trace_suite.json#degrading",
        env!("CARGO_MANIFEST_DIR")
    ))
    .expect("trace suite");
    let base = NetworkConfig::parse("up@642252800+200000:udp").unwrap();
    let ad_cfg = AdaptiveConfig {
        arch: Arch::Vgg16,
        scale: ModelScale::Full,
        tiers: vec![
            DeviceProfile::parse(&format!("edge@{macs:e}+{overhead}"))
                .unwrap(),
            DeviceProfile::parse("srv@1e15+1000").unwrap(),
        ],
        hop_nets: vec![base.with_trace(traces[0].1.clone())],
        frames: ad_frames,
        frame_period_ns: period,
        deadline_ns: period * 2,
        controller: ControllerConfig {
            window: 4,
            check_period_ns: period / 2,
            min_dwell_ns: 5 * period,
            switch_margin: 0.1,
        },
        queue: QueueKind::Calendar,
    };
    println!(
        "\n=== adaptive re-splitting @ trace_suite.json#degrading, \
         {ad_frames} frames ==="
    );
    let t0 = Instant::now();
    let ad = run_adaptive_comparison(&ad_cfg).expect("adaptive comparison");
    let ad_wall = t0.elapsed().as_secs_f64();
    let sb = ad.static_best_outcome();
    println!(
        "  static best ({})   hit-rate {:.4}",
        sb.label, sb.deadline_hit_rate
    );
    println!(
        "  adaptive (drain)       hit-rate {:.4}  ({} switches)",
        ad.adaptive_drain.deadline_hit_rate, ad.adaptive_drain.switches
    );
    println!(
        "  adaptive (drop)        hit-rate {:.4}  ({} switches, {} dropped)",
        ad.adaptive_drop.deadline_hit_rate,
        ad.adaptive_drop.switches,
        ad.adaptive_drop.dropped
    );
    println!(
        "  oracle (free switches) hit-rate {:.4}",
        ad.oracle.deadline_hit_rate
    );
    assert!(
        ad.adaptive_drain.deadline_hit_rate > sb.deadline_hit_rate,
        "adaptive (drain) must beat the best static chain on the \
         degrading trace: {} vs {}",
        ad.adaptive_drain.deadline_hit_rate,
        sb.deadline_hit_rate
    );
    assert!(
        ad.oracle.deadline_hit_rate >= ad.adaptive_drain.deadline_hit_rate,
        "the zero-cost oracle bounds the drain policy"
    );

    if let Ok(path) = std::env::var("SEI_BENCH_JSON") {
        let entries: Vec<Json> = rows
            .iter()
            .map(|&(offered, thr, mean, p99, depth, wall)| {
                json::obj(vec![
                    ("offered_fps", json::num(offered)),
                    ("throughput_fps", json::num(thr)),
                    ("mean_latency_ns", json::num(mean)),
                    ("p99_latency_ns", json::num(p99)),
                    ("max_queue_depth", json::num(depth as f64)),
                    ("wall_s", json::num(wall)),
                ])
            })
            .collect();
        let mut events = vec![
            ("streams", json::num(n_quick as f64)),
            ("calendar_events", json::num(ev_cal as f64)),
            ("calendar_events_per_sec", json::num(rate_cal)),
            ("linear_scan_events_per_sec", json::num(rate_lin)),
            ("speedup", json::num(speedup)),
        ];
        if let Some((n_full, ev, rate)) = full_scale {
            events.push(("streams_full", json::num(n_full as f64)));
            events.push(("calendar_events_full", json::num(ev as f64)));
            events.push(("calendar_events_per_sec_full", json::num(rate)));
        }
        let adaptive = json::obj(vec![
            ("trace", json::s("degrading")),
            ("frames", json::num(ad_frames as f64)),
            ("static_best_hit_rate", json::num(sb.deadline_hit_rate)),
            (
                "drain_hit_rate",
                json::num(ad.adaptive_drain.deadline_hit_rate),
            ),
            ("drop_hit_rate", json::num(ad.adaptive_drop.deadline_hit_rate)),
            ("oracle_hit_rate", json::num(ad.oracle.deadline_hit_rate)),
            (
                "drain_switches",
                json::num(ad.adaptive_drain.switches as f64),
            ),
            ("wall_s", json::num(ad_wall)),
        ]);
        let doc = json::obj(vec![
            ("bench", json::s("streaming_saturation")),
            ("quick", Json::Bool(quick)),
            ("clients", json::num(clients as f64)),
            ("frames_per_client", json::num(frames as f64)),
            ("curve", json::arr(entries)),
            ("events", json::obj(events)),
            ("adaptive", adaptive),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write bench json");
        println!("\nwrote {path}");
    }
}
