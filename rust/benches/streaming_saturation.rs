//! Bench: saturation behaviour + engine speed of the closed-loop
//! streaming simulator, plus the multi-tenant event-calendar scaling run.
//!
//! Part 1 drives a 4-client paper-scale RC deployment through an
//! offered-load ladder, records the achieved throughput / latency /
//! queue depth at each point, and checks the closed-loop contract: past
//! the bottleneck the throughput plateaus while mean and p99 latency
//! grow. Also reports the simulator's own speed (simulated frames per
//! wall-second).
//!
//! Part 2 measures the discrete-event core itself: a heterogeneous
//! tenant population (archs × RC/SC placements, slow periodic sources so
//! every pending stream keeps a timer in the event queue) is run on the
//! hierarchical timing wheel, the indexed event calendar and the
//! retained linear-scan backend at 10⁴ streams (asserting the calendar
//! sustains >= 10× the linear scan and all backends process identical
//! event counts), then scales calendar vs wheel to 10⁵ streams (CI gates
//! the wheel at >= 3× the calendar there) and the wheel alone to 10⁶
//! streams — all three scales run even under `SEI_BENCH_QUICK`. The
//! events/second figures land in the JSON document that CI gates against
//! `benches/baselines/streaming_events.json`.
//!
//! With `--features alloc-count` the bench instead runs the
//! zero-allocation smoke: a counting global allocator wraps the system
//! one, the same closed-loop stream is run at two frame counts, and the
//! allocation-count difference must be a small constant — i.e. the
//! steady-state serve loop performs zero heap allocations per frame
//! after warm-up. (The counting allocator skews every timing figure, so
//! the perf parts are skipped under that feature.)
//!
//! Part 3 runs the adaptive re-split comparison over the committed
//! degrading trace (`examples/specs/trace_suite.json#degrading`): the
//! deadline hit-rates of the best static cut chain, both adaptive switch
//! policies and the zero-cost oracle land in an `adaptive` block that CI
//! gates against `benches/baselines/adaptive_degrading.json` — the
//! outcomes are deterministic, so a drop means the controller regressed,
//! not that the runner was slow.
//!
//! Environment knobs (same contract as `netsim_micro`):
//!   SEI_BENCH_QUICK=1      fewer frames per point in Part 1
//!   SEI_BENCH_JSON=<path>  also write the results as machine-readable
//!     JSON (CI uploads it as BENCH_streaming.json)

use std::path::Path;
use std::time::Instant;

use sei::coordinator::batcher::BatchPolicy;
use sei::coordinator::{
    run_adaptive_comparison, run_hetero_stream, run_stream, AdaptiveConfig,
    ClientSpec, ControllerConfig, Fairness, ModelScale, MultiStreamConfig,
    QosRequirements, ScenarioConfig, ScenarioKind, StreamConfig,
};
use sei::model::{split_points, Arch, DeviceProfile};
use sei::netsim::trace::parse_trace_arg;
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::netsim::QueueKind;
use sei::runtime::{load_backend, load_backend_for, InferenceBackend};
use sei::util::json::{self, Json};
use sei::util::rng::SplitMix64;

/// A heterogeneous tenant population: architectures and placements cycle
/// per client, every source is slow-periodic (so between its frames the
/// stream parks exactly one pending Emit timer in the event queue — the
/// regime where an unindexed next-event scan degenerates to O(streams)
/// per pop) and emits two frames. `period_ns` sets the per-stream rate:
/// 60 s keeps aggregate load far below every resource's capacity at 10⁵
/// streams; the 10⁶ run stretches it to 600 s so admission still passes.
/// Per-client weights come from one batched [`SplitMix64::fill`] pass —
/// the fleet-scale seeding idiom (one generator walked n times, not n
/// generators).
fn mixed_clients(n: usize, period_ns: u64) -> Vec<ClientSpec> {
    let archs = [Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2];
    let mut draws = vec![0u64; n];
    SplitMix64(0xF1EE7).fill(&mut draws);
    (0..n)
        .map(|i| {
            let kind = if i % 2 == 0 {
                ScenarioKind::Rc
            } else {
                ScenarioKind::Sc { split: 5 }
            };
            let mut c = ClientSpec::new(kind);
            c.arch = archs[i % archs.len()];
            c.scale = ModelScale::Slim;
            c.frame_period_ns = period_ns;
            c.frames = 2;
            c.weight = 1 + draws[i] % 4;
            c
        })
        .collect()
}

/// Run `n` mixed tenants on the chosen event-queue backend
/// (latency-only: no model execution) and return
/// (events processed, events per wall-second, admitted streams).
fn hetero_events_run(
    engines: &[(Arch, &dyn InferenceBackend)],
    n: usize,
    period_ns: u64,
    queue: QueueKind,
) -> (u64, f64, usize) {
    let cfg = MultiStreamConfig {
        clients: mixed_clients(n, period_ns),
        hop_nets: vec![NetworkConfig::gigabit(Protocol::Udp, 0.0, 11)],
        tiers: vec![DeviceProfile::edge_gpu(), DeviceProfile::server_gpu()],
        batch: BatchPolicy::immediate(),
        fairness: Fairness::Drr,
        admission: true,
        queue,
    };
    let t0 = Instant::now();
    let report = run_hetero_stream(engines, &cfg, None, &QosRequirements::none())
        .expect("hetero stream");
    let wall = t0.elapsed().as_secs_f64();
    let events = report.aggregate.stats.events_processed;
    (events, events as f64 / wall.max(1e-9), report.admitted())
}

/// Counting global allocator for the `alloc-count` smoke: every
/// allocation and reallocation bumps one relaxed atomic; frees are
/// passed straight through. The absolute count is irrelevant — the smoke
/// differences two runs, so only per-frame *growth* matters.
#[cfg(feature = "alloc-count")]
mod alloc_count {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    static ALLOCS: AtomicU64 = AtomicU64::new(0);

    pub fn allocs() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }

    struct Counting;

    unsafe impl GlobalAlloc for Counting {
        unsafe fn alloc(&self, l: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc(l)
        }

        unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
            System.dealloc(p, l)
        }

        unsafe fn realloc(&self, p: *mut u8, l: Layout, n: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.realloc(p, l, n)
        }
    }

    #[global_allocator]
    static A: Counting = Counting;
}

/// Zero-allocation smoke: the closed-loop serve loop (wheel backend,
/// lossless UDP, latency-only) must not allocate per frame in steady
/// state. Doubling the frame count doubles the steady-state work while
/// every setup cost (arenas, queues, lanes, report assembly) stays an
/// identical O(1) number of allocations — so the count difference
/// between the two runs bounds the per-frame allocation rate, and it
/// must be a small constant, not O(frames).
#[cfg(feature = "alloc-count")]
fn alloc_smoke() {
    let engine = load_backend(Path::new("artifacts")).expect("backend");
    let qos = QosRequirements::none();
    let run = |frames: usize| -> u64 {
        let cfg = StreamConfig {
            scenario: ScenarioConfig::two_tier(
                ScenarioKind::Rc,
                NetworkConfig::gigabit(Protocol::Udp, 0.0, 3),
                DeviceProfile::edge_gpu(),
                DeviceProfile::server_gpu(),
                ModelScale::Slim,
                10_000_000, // 100 FPS per client: comfortably underloaded
            ),
            clients: 8,
            frames_per_client: frames,
            batch: BatchPolicy::immediate(),
        };
        let before = alloc_count::allocs();
        let r = sei::coordinator::run_stream_with_queue(
            &*engine,
            &cfg,
            None,
            &qos,
            QueueKind::Wheel,
        )
        .expect("alloc smoke run");
        let count = alloc_count::allocs() - before;
        assert_eq!(r.frames, 8 * frames);
        count
    };
    run(64); // warm-up: faults in code paths, sizes thread-local state
    let base = run(256);
    let double = run(512);
    let growth = double.saturating_sub(base);
    println!(
        "=== alloc-count smoke: {base} allocs @ 256 frames/client, \
         {double} @ 512, growth {growth} ==="
    );
    assert!(
        growth <= 64,
        "steady-state serve loop allocates per frame: doubling the frame \
         count added {growth} allocations (expected a small constant)"
    );
}

fn main() {
    // Under the counting allocator every timing figure is skewed, so the
    // alloc-count build runs only the zero-allocation smoke.
    #[cfg(feature = "alloc-count")]
    {
        alloc_smoke();
        return;
    }
    #[allow(unreachable_code)]
    run_bench();
}

fn run_bench() {
    let quick = std::env::var("SEI_BENCH_QUICK").is_ok();
    let frames = if quick { 96 } else { 384 };
    let clients = 4usize;
    // Per-client offered rates; aggregate = 4x. The shared 1 Gb/s uplink
    // carries ~602 kB per RC frame (~4.9 ms), so the bottleneck sits
    // around 200 aggregate FPS.
    let ladder: &[f64] = &[10.0, 25.0, 50.0, 100.0, 200.0];

    let engine = load_backend(Path::new("artifacts")).expect("backend");
    let qos = QosRequirements::ice_lab();

    println!(
        "=== streaming_saturation: RC @ VGG16 volumetrics, UDP 1 Gb/s, \
         {clients} clients x {frames} frames{} ===\n",
        if quick { " (quick)" } else { "" }
    );
    println!(
        "{:>14} {:>12} {:>12} {:>12} {:>12} {:>14}",
        "offered (agg)", "achieved", "mean lat", "p99 lat", "max depth",
        "sim frames/s"
    );

    let mut rows: Vec<(f64, f64, f64, f64, usize, f64)> = Vec::new();
    for &fps in ladder {
        let cfg = StreamConfig {
            scenario: ScenarioConfig::two_tier(
                ScenarioKind::Rc,
                NetworkConfig::gigabit(Protocol::Udp, 0.0, 7),
                DeviceProfile::edge_gpu(),
                DeviceProfile::server_gpu(),
                ModelScale::Full,
                (1e9 / fps) as u64,
            ),
            clients,
            frames_per_client: frames,
            batch: BatchPolicy::immediate(),
        };
        let t0 = Instant::now();
        let r = run_stream(&*engine, &cfg, None, &qos).expect("stream");
        let wall = t0.elapsed().as_secs_f64();
        let offered = fps * clients as f64;
        let sim_rate = r.frames as f64 / wall.max(1e-9);
        println!(
            "{:>14.0} {:>12.1} {:>9.2} ms {:>9.2} ms {:>12} {:>14.0}",
            offered,
            r.stats.throughput_fps,
            r.mean_latency_ns / 1e6,
            r.p99_latency_ns as f64 / 1e6,
            r.stats.max_queue_depth,
            sim_rate,
        );
        rows.push((
            offered,
            r.stats.throughput_fps,
            r.mean_latency_ns,
            r.p99_latency_ns as f64,
            r.stats.max_queue_depth,
            wall,
        ));
    }

    // Closed-loop contract: the last two (overloaded) points achieve the
    // same bottleneck throughput, and latency keeps growing with offered
    // load while throughput does not.
    let n = rows.len();
    let (thr_prev, thr_last) = (rows[n - 2].1, rows[n - 1].1);
    let plateau = (thr_last - thr_prev).abs() / thr_prev.max(1e-9) < 0.10;
    let latency_grows = rows[n - 1].2 > 3.0 * rows[0].2
        && rows[n - 1].3 > 3.0 * rows[0].3;
    let thr_capped = thr_last < rows[n - 1].0 * 0.9;
    println!("\nsaturation checks:");
    println!("  throughput plateaus past the bottleneck: {plateau}");
    println!("  mean/p99 latency grow under overload:    {latency_grows}");
    println!("  achieved stays below offered (overload): {thr_capped}");
    assert!(plateau, "throughput must plateau: {thr_prev} vs {thr_last}");
    assert!(latency_grows, "latency must grow under overload");
    assert!(thr_capped, "overloaded throughput must cap at the bottleneck");

    // ---- Part 2: event-calendar scaling over heterogeneous tenants ----
    let backends: Vec<(Arch, Box<dyn InferenceBackend>)> =
        [Arch::Vgg16, Arch::ResNet18, Arch::MobileNetV2]
            .into_iter()
            .map(|a| {
                (a, load_backend_for(Path::new("artifacts"), a)
                    .expect("backend"))
            })
            .collect();
    let engines: Vec<(Arch, &dyn InferenceBackend)> =
        backends.iter().map(|(a, b)| (*a, &**b)).collect();

    let minute = 60_000_000_000u64;
    let n_quick = 10_000usize;
    println!(
        "\n=== wheel vs calendar vs linear scan @ {n_quick} heterogeneous \
         streams ==="
    );
    let (ev_cal, rate_cal, adm_cal) =
        hetero_events_run(&engines, n_quick, minute, QueueKind::Calendar);
    let (ev_lin, rate_lin, adm_lin) =
        hetero_events_run(&engines, n_quick, minute, QueueKind::LinearScan);
    let (ev_whl, rate_whl, adm_whl) =
        hetero_events_run(&engines, n_quick, minute, QueueKind::Wheel);
    println!(
        "  wheel       {:>12} events  {:>14.0} events/s  ({adm_whl} \
         admitted)",
        ev_whl, rate_whl
    );
    println!(
        "  calendar    {:>12} events  {:>14.0} events/s  ({adm_cal} \
         admitted)",
        ev_cal, rate_cal
    );
    println!(
        "  linear scan {:>12} events  {:>14.0} events/s  ({adm_lin} \
         admitted)",
        ev_lin, rate_lin
    );
    let speedup = rate_cal / rate_lin.max(1e-9);
    println!("  calendar vs linear {speedup:>12.1}x");
    assert_eq!(adm_cal, n_quick, "all streams must be admitted");
    assert_eq!(
        ev_cal, ev_lin,
        "calendar and linear scan must process the same event count"
    );
    assert_eq!(
        ev_cal, ev_whl,
        "wheel and calendar must process the same event count"
    );
    assert!(
        speedup >= 10.0,
        "calendar must be >= 10x faster than the linear scan at \
         {n_quick} streams, got {speedup:.1}x"
    );

    // Calendar vs wheel at 10⁵ streams, wheel alone at 10⁶ — the CI-gated
    // fleet-scale points. Both run under SEI_BENCH_QUICK too: quick mode
    // trims Part 1's frame counts, but the scaling claim *is* this bench.
    let n_large = 100_000usize;
    println!(
        "\n=== wheel vs calendar @ {n_large} heterogeneous streams ==="
    );
    let (ev_cal_l, rate_cal_l, adm_cal_l) =
        hetero_events_run(&engines, n_large, minute, QueueKind::Calendar);
    let (ev_whl_l, rate_whl_l, adm_whl_l) =
        hetero_events_run(&engines, n_large, minute, QueueKind::Wheel);
    let wheel_speedup_large = rate_whl_l / rate_cal_l.max(1e-9);
    println!(
        "  wheel       {:>12} events  {:>14.0} events/s  ({adm_whl_l} \
         admitted)",
        ev_whl_l, rate_whl_l
    );
    println!(
        "  calendar    {:>12} events  {:>14.0} events/s  ({adm_cal_l} \
         admitted)",
        ev_cal_l, rate_cal_l
    );
    println!("  wheel vs calendar {wheel_speedup_large:>12.1}x");
    assert_eq!(adm_cal_l, n_large, "all streams must be admitted");
    assert_eq!(
        ev_cal_l, ev_whl_l,
        "wheel and calendar must process the same event count at 10^5"
    );

    // 10⁶ tenants: sources stretch to one frame per 10 minutes so the
    // aggregate offered load (and therefore admission) matches the 10⁵
    // point; the event population — one parked timer per pending stream —
    // is 10× larger, which is the regime the wheel exists for.
    let n_xl = 1_000_000usize;
    println!("\n=== timing wheel @ {n_xl} heterogeneous streams ===");
    let (ev_xl, rate_xl, adm_xl) =
        hetero_events_run(&engines, n_xl, 10 * minute, QueueKind::Wheel);
    println!(
        "  wheel       {:>12} events  {:>14.0} events/s  ({adm_xl} \
         admitted)",
        ev_xl, rate_xl
    );
    assert_eq!(adm_xl, n_xl, "all 10^6 streams must be admitted");

    // ---- Part 3: adaptive re-splitting over the committed trace ----
    // Same calibration as tests/trace_semantics.rs: the degrading entry's
    // rates are derived from VGG16's own latent volumetrics, the edge is
    // tuned so the deep low-latent cut runs at 1.02x the frame period.
    let period: u64 = 10_000_000;
    let ad_frames = 60usize;
    let points = split_points(&Arch::Vgg16.full_network());
    let n_cand = points.len() - 1;
    let min_bytes =
        (0..n_cand).map(|i| points[i].latent_bytes()).min().unwrap();
    let d = (0..n_cand)
        .find(|&i| points[i].latent_bytes() == min_bytes)
        .unwrap();
    let (head_d, _) = points[d].split_compute();
    let overhead = 10_000u64;
    let macs =
        head_d as f64 / ((1.02 * period as f64 - overhead as f64) / 1e9);
    let traces = parse_trace_arg(&format!(
        "{}/../examples/specs/trace_suite.json#degrading",
        env!("CARGO_MANIFEST_DIR")
    ))
    .expect("trace suite");
    let base = NetworkConfig::parse("up@642252800+200000:udp").unwrap();
    let ad_cfg = AdaptiveConfig {
        arch: Arch::Vgg16,
        scale: ModelScale::Full,
        tiers: vec![
            DeviceProfile::parse(&format!("edge@{macs:e}+{overhead}"))
                .unwrap(),
            DeviceProfile::parse("srv@1e15+1000").unwrap(),
        ],
        hop_nets: vec![base.with_trace(traces[0].1.clone())],
        frames: ad_frames,
        frame_period_ns: period,
        deadline_ns: period * 2,
        controller: ControllerConfig {
            window: 4,
            check_period_ns: period / 2,
            min_dwell_ns: 5 * period,
            switch_margin: 0.1,
        },
        queue: QueueKind::Calendar,
    };
    println!(
        "\n=== adaptive re-splitting @ trace_suite.json#degrading, \
         {ad_frames} frames ==="
    );
    let t0 = Instant::now();
    let ad = run_adaptive_comparison(&ad_cfg).expect("adaptive comparison");
    let ad_wall = t0.elapsed().as_secs_f64();
    let sb = ad.static_best_outcome();
    println!(
        "  static best ({})   hit-rate {:.4}",
        sb.label, sb.deadline_hit_rate
    );
    println!(
        "  adaptive (drain)       hit-rate {:.4}  ({} switches)",
        ad.adaptive_drain.deadline_hit_rate, ad.adaptive_drain.switches
    );
    println!(
        "  adaptive (drop)        hit-rate {:.4}  ({} switches, {} dropped)",
        ad.adaptive_drop.deadline_hit_rate,
        ad.adaptive_drop.switches,
        ad.adaptive_drop.dropped
    );
    println!(
        "  oracle (free switches) hit-rate {:.4}",
        ad.oracle.deadline_hit_rate
    );
    assert!(
        ad.adaptive_drain.deadline_hit_rate > sb.deadline_hit_rate,
        "adaptive (drain) must beat the best static chain on the \
         degrading trace: {} vs {}",
        ad.adaptive_drain.deadline_hit_rate,
        sb.deadline_hit_rate
    );
    assert!(
        ad.oracle.deadline_hit_rate >= ad.adaptive_drain.deadline_hit_rate,
        "the zero-cost oracle bounds the drain policy"
    );

    if let Ok(path) = std::env::var("SEI_BENCH_JSON") {
        let entries: Vec<Json> = rows
            .iter()
            .map(|&(offered, thr, mean, p99, depth, wall)| {
                json::obj(vec![
                    ("offered_fps", json::num(offered)),
                    ("throughput_fps", json::num(thr)),
                    ("mean_latency_ns", json::num(mean)),
                    ("p99_latency_ns", json::num(p99)),
                    ("max_queue_depth", json::num(depth as f64)),
                    ("wall_s", json::num(wall)),
                ])
            })
            .collect();
        let events = vec![
            ("streams", json::num(n_quick as f64)),
            ("calendar_events", json::num(ev_cal as f64)),
            ("calendar_events_per_sec", json::num(rate_cal)),
            ("linear_scan_events_per_sec", json::num(rate_lin)),
            ("wheel_events_per_sec", json::num(rate_whl)),
            ("speedup", json::num(speedup)),
            ("streams_large", json::num(n_large as f64)),
            ("calendar_events_per_sec_large", json::num(rate_cal_l)),
            ("wheel_events_per_sec_large", json::num(rate_whl_l)),
            ("wheel_speedup_large", json::num(wheel_speedup_large)),
            ("streams_xl", json::num(n_xl as f64)),
            ("wheel_events_xl", json::num(ev_xl as f64)),
            ("wheel_events_per_sec_xl", json::num(rate_xl)),
        ];
        let adaptive = json::obj(vec![
            ("trace", json::s("degrading")),
            ("frames", json::num(ad_frames as f64)),
            ("static_best_hit_rate", json::num(sb.deadline_hit_rate)),
            (
                "drain_hit_rate",
                json::num(ad.adaptive_drain.deadline_hit_rate),
            ),
            ("drop_hit_rate", json::num(ad.adaptive_drop.deadline_hit_rate)),
            ("oracle_hit_rate", json::num(ad.oracle.deadline_hit_rate)),
            (
                "drain_switches",
                json::num(ad.adaptive_drain.switches as f64),
            ),
            ("wall_s", json::num(ad_wall)),
        ]);
        let doc = json::obj(vec![
            ("bench", json::s("streaming_saturation")),
            ("quick", Json::Bool(quick)),
            ("clients", json::num(clients as f64)),
            ("frames_per_client", json::num(frames as f64)),
            ("curve", json::arr(entries)),
            ("events", json::obj(events)),
            ("adaptive", adaptive),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write bench json");
        println!("\nwrote {path}");
    }
}
