//! Bench: wall-clock speedup of the design-space sweep engine's worker
//! pool over sequential execution of the same grid — and a determinism
//! check that every thread count produces a byte-identical report.
//!
//! Environment knobs (same contract as `netsim_micro`):
//!   SEI_BENCH_QUICK=1      smaller grid / fewer frames
//!   SEI_BENCH_JSON=<path>  also write the stats as machine-readable JSON

use std::path::Path;
use std::time::Instant;

use sei::coordinator::{
    run_sweep, ScenarioKind, SweepMode, SweepSpec,
};
use sei::netsim::transfer::Protocol;
use sei::runtime::load_backend_for;
use sei::util::json::{self, Json};

fn main() {
    let quick = std::env::var("SEI_BENCH_QUICK").is_ok();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let mut spec = SweepSpec::new("sweep_parallel");
    spec.mode = SweepMode::Full;
    spec.scenarios = vec![
        ScenarioKind::Lc,
        ScenarioKind::Rc,
        ScenarioKind::Sc { split: 5 },
        ScenarioKind::Sc { split: 9 },
        ScenarioKind::Sc { split: 11 },
        ScenarioKind::Sc { split: 13 },
        ScenarioKind::Sc { split: 15 },
    ];
    spec.protocols = vec![Protocol::Tcp, Protocol::Udp];
    spec.loss_rates = if quick {
        vec![0.0, 0.05]
    } else {
        vec![0.0, 0.02, 0.05, 0.08]
    };
    spec.frames = if quick { 48 } else { 192 };
    spec.seeds_per_point = if quick { 1 } else { 2 };
    spec.frame_period_ns = 50_000_000;
    spec.max_latency_ms = 50.0;
    spec.min_accuracy = 0.9;

    let jobs = spec.expand().expect("spec").len();
    println!(
        "=== sweep_parallel: {} grid points x {} frames x {} seed(s), \
         {cores} core(s) available{} ===\n",
        jobs,
        spec.frames,
        spec.seeds_per_point,
        if quick { " (quick)" } else { "" }
    );

    let factory =
        |arch| load_backend_for(Path::new("artifacts"), arch);
    let mut results: Vec<(usize, f64, f64)> = Vec::new(); // (threads, s, x)
    let mut baseline_json = String::new();
    let mut baseline_s = 0.0f64;
    for &threads in &[1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let report = run_sweep(&spec, threads, &factory).expect("sweep");
        let wall = t0.elapsed().as_secs_f64();
        let j = report.to_json().to_string();
        if threads == 1 {
            baseline_json = j.clone();
            baseline_s = wall;
        } else {
            assert_eq!(
                j, baseline_json,
                "sweep report must be identical at every thread count"
            );
        }
        let speedup = baseline_s / wall;
        println!(
            "threads {threads:>2}   wall {wall:>7.3} s   speedup {speedup:>5.2}x\
             {}",
            if threads == 1 { "   (baseline)" } else { "" }
        );
        results.push((threads, wall, speedup));
    }
    println!(
        "\ndeterminism: all reports byte-identical ({} points, {} bytes of \
         JSON)",
        jobs,
        baseline_json.len()
    );
    let best = results
        .iter()
        .map(|&(_, _, s)| s)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "best speedup {best:.2}x over sequential on {cores} core(s)"
    );

    if let Ok(path) = std::env::var("SEI_BENCH_JSON") {
        let entries: Vec<Json> = results
            .iter()
            .map(|&(threads, wall, speedup)| {
                json::obj(vec![
                    ("threads", json::num(threads as f64)),
                    ("wall_s", json::num(wall)),
                    ("speedup", json::num(speedup)),
                ])
            })
            .collect();
        let doc = json::obj(vec![
            ("bench", json::s("sweep_parallel")),
            ("quick", Json::Bool(quick)),
            ("cores", json::num(cores as f64)),
            ("grid_points", json::num(jobs as f64)),
            ("results", json::arr(entries)),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write bench json");
        println!("\nwrote {path}");
    }
}
