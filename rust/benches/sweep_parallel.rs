//! Bench: the sweep evaluation core.
//!
//! Three measurements, all with byte-identical-output checks:
//!   1. wall-clock scaling of the work-stealing pool over sequential
//!      execution of the same grid (1/2/4/8 threads);
//!   2. work-stealing vs the retained fixed-wave scheduler on a skewed
//!      job mix (one 32-client heavy job per wave of light jobs) — the
//!      structural win of dropping the per-wave barrier;
//!   3. the bound-guided prefilter's skip ratio and wall-clock saving on
//!      a grid with provably QoS-infeasible far-latency points.
//!
//! Environment knobs (same contract as `netsim_micro`):
//!   SEI_BENCH_QUICK=1      smaller grid / fewer frames
//!   SEI_BENCH_JSON=<path>  also write the stats as machine-readable JSON

use std::path::Path;
use std::time::Instant;

use sei::coordinator::{
    run_sweep, run_sweep_with, ScenarioKind, SweepMode, SweepScheduler,
    SweepSpec,
};
use sei::netsim::transfer::Protocol;
use sei::runtime::load_backend_for;
use sei::util::json::{self, Json};

fn main() {
    let quick = std::env::var("SEI_BENCH_QUICK").is_ok();
    let cores = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);

    let mut spec = SweepSpec::new("sweep_parallel");
    spec.mode = SweepMode::Full;
    spec.scenarios = vec![
        ScenarioKind::Lc,
        ScenarioKind::Rc,
        ScenarioKind::Sc { split: 5 },
        ScenarioKind::Sc { split: 9 },
        ScenarioKind::Sc { split: 11 },
        ScenarioKind::Sc { split: 13 },
        ScenarioKind::Sc { split: 15 },
    ];
    spec.protocols = vec![Protocol::Tcp, Protocol::Udp];
    spec.loss_rates = if quick {
        vec![0.0, 0.05]
    } else {
        vec![0.0, 0.02, 0.05, 0.08]
    };
    spec.frames = if quick { 48 } else { 192 };
    spec.seeds_per_point = if quick { 1 } else { 2 };
    spec.frame_period_ns = 50_000_000;
    spec.max_latency_ms = 50.0;
    spec.min_accuracy = 0.9;

    let jobs = spec.expand().expect("spec").len();
    println!(
        "=== sweep_parallel: {} grid points x {} frames x {} seed(s), \
         {cores} core(s) available{} ===\n",
        jobs,
        spec.frames,
        spec.seeds_per_point,
        if quick { " (quick)" } else { "" }
    );

    let factory =
        |arch| load_backend_for(Path::new("artifacts"), arch);
    let mut results: Vec<(usize, f64, f64)> = Vec::new(); // (threads, s, x)
    let mut baseline_json = String::new();
    let mut baseline_s = 0.0f64;
    for &threads in &[1usize, 2, 4, 8] {
        let t0 = Instant::now();
        let report = run_sweep(&spec, threads, &factory).expect("sweep");
        let wall = t0.elapsed().as_secs_f64();
        let j = report.to_json().to_string();
        if threads == 1 {
            baseline_json = j.clone();
            baseline_s = wall;
        } else {
            assert_eq!(
                j, baseline_json,
                "sweep report must be identical at every thread count"
            );
        }
        let speedup = baseline_s / wall;
        println!(
            "threads {threads:>2}   wall {wall:>7.3} s   speedup {speedup:>5.2}x\
             {}",
            if threads == 1 { "   (baseline)" } else { "" }
        );
        results.push((threads, wall, speedup));
    }
    println!(
        "\ndeterminism: all reports byte-identical ({} points, {} bytes of \
         JSON)",
        jobs,
        baseline_json.len()
    );
    let best = results
        .iter()
        .map(|&(_, _, s)| s)
        .fold(f64::NEG_INFINITY, f64::max);
    println!(
        "best speedup {best:.2}x over sequential on {cores} core(s)"
    );

    // --- scheduler face-off on a skewed mix ----------------------------
    // Eight client counts per scenario, the last 32x heavier: under the
    // wave scheduler every wave of 8 contains exactly one heavy job, so
    // seven workers idle at the barrier while it finishes; work stealing
    // lets them run ahead into the next jobs and overlaps the heavies.
    const SCHED_THREADS: usize = 8;
    let mut skew = SweepSpec::new("sweep_skew");
    skew.mode = SweepMode::Full;
    skew.scenarios = vec![
        ScenarioKind::Lc,
        ScenarioKind::Rc,
        ScenarioKind::Sc { split: 5 },
        ScenarioKind::Sc { split: 11 },
    ];
    skew.clients = vec![1, 1, 1, 1, 1, 1, 1, 32];
    skew.frames = if quick { 32 } else { 96 };
    skew.frame_period_ns = 50_000_000;
    skew.max_latency_ms = 50.0;
    skew.min_accuracy = 0.9;
    let skew_jobs = skew.expand().expect("skew spec").len();

    let t0 = Instant::now();
    let by_waves =
        run_sweep_with(&skew, SCHED_THREADS, SweepScheduler::Waves, &factory)
            .expect("waves");
    let waves_s = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let by_stealing = run_sweep_with(
        &skew,
        SCHED_THREADS,
        SweepScheduler::Stealing,
        &factory,
    )
    .expect("stealing");
    let stealing_s = t0.elapsed().as_secs_f64();
    assert_eq!(
        by_waves.to_json().to_string(),
        by_stealing.to_json().to_string(),
        "schedulers must be output-equivalent"
    );
    let sched_speedup = waves_s / stealing_s;
    let jobs_per_sec = skew_jobs as f64 / stealing_s;
    println!(
        "\nskewed mix ({skew_jobs} jobs, one 32-client heavy per wave of 8, \
         {SCHED_THREADS} threads):\n\
         waves    {waves_s:>7.3} s\n\
         stealing {stealing_s:>7.3} s   speedup {sched_speedup:>5.2}x   \
         ({jobs_per_sec:.2} jobs/s)"
    );

    // --- bound-guided prefilter ----------------------------------------
    // A far-latency axis (200 ms of propagation against a 50 ms
    // deadline) makes half the grid provably infeasible: every scenario
    // here crosses the network, so each one's 200 ms twin is skipped and
    // the ratio is exactly 1/2 (LC would stay local and dilute it).
    // Frontier preservation is asserted by the integration tests; here
    // we measure the ratio and the saving.
    let mut pf = SweepSpec::new("sweep_prefilter");
    pf.mode = SweepMode::Full;
    pf.scenarios = vec![
        ScenarioKind::Rc,
        ScenarioKind::Sc { split: 5 },
        ScenarioKind::Sc { split: 9 },
        ScenarioKind::Sc { split: 11 },
    ];
    pf.protocols = vec![Protocol::Tcp, Protocol::Udp];
    pf.latencies_us = vec![1.0, 200_000.0];
    pf.frames = if quick { 48 } else { 192 };
    pf.frame_period_ns = 50_000_000;
    pf.max_latency_ms = 50.0;
    pf.min_accuracy = 0.9;
    let t0 = Instant::now();
    let off = run_sweep(&pf, SCHED_THREADS, &factory).expect("prefilter off");
    let off_s = t0.elapsed().as_secs_f64();
    pf.prefilter = true;
    let t0 = Instant::now();
    let on = run_sweep(&pf, SCHED_THREADS, &factory).expect("prefilter on");
    let on_s = t0.elapsed().as_secs_f64();
    assert_eq!(off.skipped, 0, "prefilter off must simulate everything");
    assert_eq!(
        2 * on.skipped,
        on.points.len(),
        "exactly the 200 ms half of the grid must be skipped"
    );
    assert_eq!(
        off.pareto, on.pareto,
        "the prefilter must not move the Pareto frontier"
    );
    let skip_ratio = on.skipped as f64 / on.points.len() as f64;
    let pf_speedup = off_s / on_s;
    println!(
        "\nprefilter ({} points, {} provably infeasible):\n\
         off {off_s:>7.3} s\n\
         on  {on_s:>7.3} s   speedup {pf_speedup:>5.2}x   \
         (skip ratio {skip_ratio:.3})",
        on.points.len(),
        on.skipped
    );

    if let Ok(path) = std::env::var("SEI_BENCH_JSON") {
        let entries: Vec<Json> = results
            .iter()
            .map(|&(threads, wall, speedup)| {
                json::obj(vec![
                    ("threads", json::num(threads as f64)),
                    ("wall_s", json::num(wall)),
                    ("speedup", json::num(speedup)),
                ])
            })
            .collect();
        let doc = json::obj(vec![
            ("bench", json::s("sweep_parallel")),
            ("quick", Json::Bool(quick)),
            ("cores", json::num(cores as f64)),
            ("grid_points", json::num(jobs as f64)),
            ("results", json::arr(entries)),
            (
                "scheduler",
                json::obj(vec![
                    ("threads", json::num(SCHED_THREADS as f64)),
                    ("jobs", json::num(skew_jobs as f64)),
                    ("heavy_clients", json::num(32.0)),
                    ("waves_wall_s", json::num(waves_s)),
                    ("stealing_wall_s", json::num(stealing_s)),
                    ("stealing_speedup", json::num(sched_speedup)),
                    ("stealing_jobs_per_sec", json::num(jobs_per_sec)),
                ]),
            ),
            (
                "prefilter",
                json::obj(vec![
                    ("points", json::num(on.points.len() as f64)),
                    ("skipped", json::num(on.skipped as f64)),
                    ("skip_ratio", json::num(skip_ratio)),
                    ("off_wall_s", json::num(off_s)),
                    ("on_wall_s", json::num(on_s)),
                    ("speedup", json::num(pf_speedup)),
                ]),
            ),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write bench json");
        println!("\nwrote {path}");
    }
}
