//! Microbenchmarks of the discrete-event simulator — the L3 hot path
//! (EXPERIMENTS.md §Perf tracks these before/after optimization).
//!
//! Environment knobs (used by the CI bench-smoke step):
//!   SEI_BENCH_QUICK=1      reduced warmup/measure budget per benchmark
//!   SEI_BENCH_JSON=<path>  also write the stats as machine-readable JSON
//!                          (the `BENCH_netsim.json` perf trajectory)

use sei::netsim::event::EventQueue;
use sei::netsim::link::{Link, LinkConfig};
use sei::netsim::tcp::{self, TcpConfig, TcpState};
use sei::netsim::transfer::{Channel, NetworkConfig, Protocol};
use sei::netsim::udp::{self, UdpConfig};
use sei::netsim::Dir;
use sei::util::bench::{black_box, Bencher, Stats};
use sei::util::json::{self, Json};
use sei::util::rng::Rng;

fn links(loss: f64, seed: u64) -> (Link, Link) {
    let cfg = LinkConfig::basic(100_000, 1e9, loss);
    let mut rng = Rng::new(seed);
    (Link::new(cfg.clone(), rng.fork()), Link::new(cfg, rng.fork()))
}

fn main() {
    let quick = std::env::var("SEI_BENCH_QUICK").is_ok();
    println!(
        "=== netsim microbenchmarks{} ===\n",
        if quick { " (quick)" } else { "" }
    );
    let b = if quick { Bencher::quick() } else { Bencher::default() };
    let mut results: Vec<(String, Stats)> = Vec::new();

    // Event queue throughput.
    for n in [1_000usize, 100_000] {
        let name = format!("event_queue_schedule_pop_{n}");
        let st = b.bench(&name, || {
            let mut q = EventQueue::new();
            let mut rng = Rng::new(7);
            for _ in 0..n {
                q.schedule(rng.below(1_000_000), 0u32);
            }
            while q.pop().is_some() {}
        });
        println!(
            "      -> {:.1} M events/s",
            n as f64 / (st.mean_ns / 1e9) / 1e6
        );
        results.push((name, st));
    }

    // PRNG.
    let st = b.bench("rng_next_u64_x1000", || {
        let mut r = Rng::new(1);
        let mut acc = 0u64;
        for _ in 0..1000 {
            acc ^= r.next_u64();
        }
        black_box(acc);
    });
    results.push(("rng_next_u64_x1000".to_string(), st));

    // Raw link sends.
    let st = b.bench("link_send_x1000", || {
        let (mut l, _) = links(0.02, 3);
        for i in 0..1000u64 {
            black_box(l.send(i * 10_000, 1500));
        }
    });
    results.push(("link_send_x1000".to_string(), st));

    // TCP message transfers at several sizes and loss rates.
    for (len, loss) in [(2_048u64, 0.0), (803_000, 0.0), (803_000, 0.03),
                        (803_000, 0.10)] {
        let name = format!("tcp_send_{}kB_loss{:.0}%", len / 1000,
                           loss * 100.0);
        let mut seed = 0u64;
        let st = b.bench(&name, || {
            seed += 1;
            let (mut d, mut a) = links(loss, seed);
            let cfg = TcpConfig::default();
            let mut s = TcpState::new(&cfg);
            black_box(
                tcp::send_message(&cfg, &mut s, &mut d, &mut a, len, 0)
                    .unwrap(),
            );
        });
        let mbps = len as f64 / (st.mean_ns / 1e9) / 1e6;
        println!("      -> {mbps:.0} MB/s of simulated payload");
        results.push((name, st));
    }

    // UDP burst.
    let mut seed = 0u64;
    let st = b.bench("udp_send_803kB_loss10%", || {
        seed += 1;
        let (mut l, _) = links(0.10, seed);
        black_box(udp::send_message(&UdpConfig::default(), &mut l,
                                    803_000, 0));
    });
    results.push(("udp_send_803kB_loss10%".to_string(), st));

    // Whole-channel round trip (the scenario engine's inner loop).
    let mut ch = Channel::new(NetworkConfig::gigabit(Protocol::Tcp, 0.02, 5));
    let mut frame = 0u64;
    let st = b.bench("channel_frame_roundtrip_2kB", || {
        frame += 1;
        ch.advance_to(frame * 50_000_000);
        black_box(ch.send(Dir::Up, 2048).unwrap());
        black_box(ch.send(Dir::Down, 40).unwrap());
    });
    results.push(("channel_frame_roundtrip_2kB".to_string(), st));

    if let Ok(path) = std::env::var("SEI_BENCH_JSON") {
        let entries: Vec<Json> = results
            .iter()
            .map(|(name, st)| {
                json::obj(vec![
                    ("name", json::s(name)),
                    ("mean_ns", json::num(st.mean_ns)),
                    ("median_ns", json::num(st.median_ns)),
                    ("p99_ns", json::num(st.p99_ns)),
                    ("min_ns", json::num(st.min_ns)),
                    ("max_ns", json::num(st.max_ns)),
                    ("iters", json::num(st.iters as f64)),
                ])
            })
            .collect();
        let doc = json::obj(vec![
            ("bench", json::s("netsim_micro")),
            ("quick", Json::Bool(quick)),
            ("results", json::arr(entries)),
        ]);
        std::fs::write(&path, doc.to_string()).expect("write bench json");
        println!("\nwrote {path}");
    }
}
