//! Microbenchmarks of the discrete-event simulator — the L3 hot path
//! (EXPERIMENTS.md §Perf tracks these before/after optimization).

use sei::netsim::event::EventQueue;
use sei::netsim::link::{Link, LinkConfig};
use sei::netsim::tcp::{self, TcpConfig, TcpState};
use sei::netsim::transfer::{Channel, NetworkConfig, Protocol};
use sei::netsim::udp::{self, UdpConfig};
use sei::netsim::Dir;
use sei::util::bench::{black_box, Bencher};
use sei::util::rng::Rng;

fn links(loss: f64, seed: u64) -> (Link, Link) {
    let cfg = LinkConfig::basic(100_000, 1e9, loss);
    let mut rng = Rng::new(seed);
    (Link::new(cfg.clone(), rng.fork()), Link::new(cfg, rng.fork()))
}

fn main() {
    println!("=== netsim microbenchmarks ===\n");
    let b = Bencher::default();

    // Event queue throughput.
    for n in [1_000usize, 100_000] {
        let st = b.bench(&format!("event_queue_schedule_pop_{n}"), || {
            let mut q = EventQueue::new();
            let mut rng = Rng::new(7);
            for _ in 0..n {
                q.schedule(rng.below(1_000_000), 0u32);
            }
            while q.pop().is_some() {}
        });
        println!(
            "      -> {:.1} M events/s",
            n as f64 / (st.mean_ns / 1e9) / 1e6
        );
    }

    // PRNG.
    b.bench("rng_next_u64_x1000", || {
        let mut r = Rng::new(1);
        let mut acc = 0u64;
        for _ in 0..1000 {
            acc ^= r.next_u64();
        }
        black_box(acc);
    });

    // Raw link sends.
    b.bench("link_send_x1000", || {
        let (mut l, _) = links(0.02, 3);
        for i in 0..1000u64 {
            black_box(l.send(i * 10_000, 1500));
        }
    });

    // TCP message transfers at several sizes and loss rates.
    for (len, loss) in [(2_048u64, 0.0), (803_000, 0.0), (803_000, 0.03),
                        (803_000, 0.10)] {
        let name = format!("tcp_send_{}kB_loss{:.0}%", len / 1000,
                           loss * 100.0);
        let mut seed = 0u64;
        let st = b.bench(&name, || {
            seed += 1;
            let (mut d, mut a) = links(loss, seed);
            let cfg = TcpConfig::default();
            let mut s = TcpState::new(&cfg);
            black_box(
                tcp::send_message(&cfg, &mut s, &mut d, &mut a, len, 0)
                    .unwrap(),
            );
        });
        let mbps = len as f64 / (st.mean_ns / 1e9) / 1e6;
        println!("      -> {mbps:.0} MB/s of simulated payload");
    }

    // UDP burst.
    let mut seed = 0u64;
    b.bench("udp_send_803kB_loss10%", || {
        seed += 1;
        let (mut l, _) = links(0.10, seed);
        black_box(udp::send_message(&UdpConfig::default(), &mut l,
                                    803_000, 0));
    });

    // Whole-channel round trip (the scenario engine's inner loop).
    let mut ch = Channel::new(NetworkConfig::gigabit(Protocol::Tcp, 0.02, 5));
    let mut frame = 0u64;
    b.bench("channel_frame_roundtrip_2kB", || {
        frame += 1;
        ch.advance_to(frame * 50_000_000);
        black_box(ch.send(Dir::Up, 2048).unwrap());
        black_box(ch.send(Dir::Down, 40).unwrap());
    });
}
