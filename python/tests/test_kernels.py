"""L1 Pallas kernels vs pure-jnp oracles (hypothesis shape/dtype sweeps)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as K
from compile.kernels import ref as R
from compile.kernels import saliency as SK


def rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), jnp.float32)


# ----------------------------------------------------------------- matmul --

@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 70),
    k=st.integers(1, 70),
    n=st.integers(1, 70),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_random_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, m, k), rand(rng, k, n)
    got = K.matmul(x, y, bm=32, bn=32, bk=32)
    np.testing.assert_allclose(got, R.matmul_ref(x, y), rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 128, 64),
                                   (64, 256, 128)])
def test_matmul_block_multiple_shapes(m, k, n):
    rng = np.random.default_rng(0)
    x, y = rand(rng, m, k), rand(rng, k, n)
    np.testing.assert_allclose(K.matmul(x, y), R.matmul_ref(x, y),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 8), (64, 64, 64)])
def test_matmul_block_shape_invariance(bm, bn, bk):
    """Result must not depend on the tiling."""
    rng = np.random.default_rng(1)
    x, y = rand(rng, 50, 33, ), rand(rng, 33, 21)
    got = K.matmul(x, y, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, R.matmul_ref(x, y), rtol=1e-5, atol=1e-5)


def test_matmul_identity():
    rng = np.random.default_rng(2)
    x = rand(rng, 40, 40)
    np.testing.assert_allclose(K.matmul(x, jnp.eye(40)), x,
                               rtol=1e-6, atol=1e-6)


def test_matmul_zero():
    x = jnp.zeros((17, 23), jnp.float32)
    y = jnp.zeros((23, 9), jnp.float32)
    assert float(jnp.abs(K.matmul(x, y)).max()) == 0.0


def test_vmem_and_mxu_estimates():
    assert K.vmem_bytes(128, 128, 128) == 4 * 3 * 128 * 128
    assert K.vmem_bytes(128, 128, 128) < 16 * 1024 * 1024  # fits VMEM
    assert K.mxu_utilization(128, 128, 128) == 1.0
    assert 0.0 < K.mxu_utilization(129, 128, 128) < 1.0


# --------------------------------------------------------------- saliency --

@settings(max_examples=20, deadline=None)
@given(
    b=st.integers(1, 8),
    z=st.integers(1, 24),
    h=st.sampled_from([1, 2, 4, 7, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_saliency_matches_ref(b, z, h, seed):
    rng = np.random.default_rng(seed)
    f = rand(rng, b, z, h, h)
    a = rand(rng, b, z)
    np.testing.assert_allclose(SK.saliency_reduce(f, a),
                               R.saliency_ref(f, a), rtol=1e-5, atol=1e-6)


def test_saliency_relu_clips_negative_cam():
    f = jnp.ones((2, 3, 4, 4), jnp.float32)
    a = -jnp.ones((2, 3), jnp.float32)
    out = SK.saliency_reduce(f, a)
    np.testing.assert_allclose(out, jnp.zeros(2), atol=0)


def test_saliency_scale_equivariance():
    rng = np.random.default_rng(3)
    f = jnp.abs(rand(rng, 2, 4, 4, 4))
    a = jnp.abs(rand(rng, 2, 4))
    np.testing.assert_allclose(SK.saliency_reduce(f, 2.0 * a),
                               2.0 * SK.saliency_reduce(f, a), rtol=1e-5)


def test_saliency_nonneg():
    rng = np.random.default_rng(4)
    f, a = rand(rng, 4, 8, 4, 4), rand(rng, 4, 8)
    assert float(SK.saliency_reduce(f, a).min()) >= 0.0
