"""L2 model: shape contracts, split consistency, pallas-vs-jnp forward."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.ModelConfig(width_mult=0.125)
PARAMS = M.init_params(CFG, seed=0)
RNG = np.random.default_rng(0)
X = jnp.asarray(RNG.uniform(0, 1, (4, 3, 32, 32)), jnp.float32)


def test_forward_shape():
    assert M.forward(CFG, PARAMS, X).shape == (4, 10)


def test_feature_shapes_match_config():
    for i in range(M.NUM_FEATURE_LAYERS):
        feat = M.forward_features(CFG, PARAMS, X, upto=i)
        assert feat.shape[1:] == CFG.feature_shape(i), f"layer {i}"


def test_split_consistency_every_layer():
    """head(0..i) + forward_from(i+1..) == full forward, for all i."""
    full = M.forward(CFG, PARAMS, X)
    for i in range(M.NUM_FEATURE_LAYERS - 1):
        feat = M.forward_features(CFG, PARAMS, X, upto=i)
        logits = M.forward_from(CFG, PARAMS, feat, i + 1)
        np.testing.assert_allclose(logits, full, rtol=1e-5, atol=1e-5)


def test_pallas_forward_matches_jnp():
    pcfg = M.ModelConfig(width_mult=0.125, use_pallas=True)
    ref = M.forward(CFG, PARAMS, X)
    got = M.forward(pcfg, PARAMS, X)
    np.testing.assert_allclose(got, ref, rtol=2e-4, atol=2e-4)


def test_param_names_cover_params():
    assert set(M.param_names(CFG)) == set(PARAMS.keys())


def test_total_params_matches_actual():
    actual = sum(int(np.prod(v.shape)) for v in PARAMS.values())
    assert M.total_params(CFG) == actual


def test_layer_stats_shapes():
    rows = M.layer_stats(CFG)
    assert len(rows) == M.NUM_FEATURE_LAYERS + 2
    # pools carry no params
    for name, shape, p, ma in rows:
        if name.endswith("_pool"):
            assert p == 0 and ma == 0


def test_vgg16_full_width_param_count():
    """Feature-extractor params of the *full* VGG16 topology (width 1.0)
    must match the canonical 14,714,688 (conv layers incl. biases)."""
    cfg = M.ModelConfig(width_mult=1.0, img_size=32)
    conv_params = sum(r[2] for r in M.layer_stats(cfg)
                      if r[0].startswith("block"))
    assert conv_params == 14_714_688


def test_loss_decreases_one_step():
    from compile import train as T
    loss_fn = functools.partial(M.loss_ce, CFG)
    y = jnp.asarray(RNG.integers(0, 10, 4), jnp.int32)
    step = T.make_train_step(loss_fn, 1e-3)
    st = T.adam_init(PARAMS)
    p, st, l0 = step(PARAMS, st, X, y)
    for _ in range(20):
        p, st, l = step(p, st, X, y)
    assert float(l) < float(l0)


def test_accuracy_bounds():
    y = jnp.asarray(RNG.integers(0, 10, 4), jnp.int32)
    a = float(M.accuracy(CFG, PARAMS, X, y))
    assert 0.0 <= a <= 1.0


def test_mse_task_loss_zero_at_perfect_onehot():
    class FakeCfg(M.ModelConfig):
        pass
    y = jnp.asarray([1, 2, 3, 4], jnp.int32)
    onehot = jax.nn.one_hot(y, 10)
    # loss formula check (not through the net): perfect logits -> 0
    assert float(jnp.mean(jnp.sum((onehot - onehot) ** 2, axis=1))) == 0.0
