"""Artifact/manifest schema contract with the Rust loader.

These run against the real `artifacts/` directory when it exists (built by
`make artifacts`); they are skipped otherwise so `pytest` works on a fresh
checkout.
"""

import json
import os

import numpy as np
import pytest

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="artifacts not built")


@pytest.fixture(scope="module")
def manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_top_level(manifest):
    for key in ("model", "dataset", "cs_curve", "split_eval",
                "executables", "fixtures"):
        assert key in manifest


def test_every_hlo_file_exists_and_parses_header(manifest):
    for ex in manifest["executables"]:
        p = os.path.join(ART, ex["hlo"])
        assert os.path.exists(p), ex["name"]
        head = open(p).read(200)
        assert "HloModule" in head, ex["name"]


def test_every_weight_file_matches_shape(manifest):
    seen = set()
    for ex in manifest["executables"]:
        for w in ex["weights"]:
            if w["file"] in seen:
                continue
            seen.add(w["file"])
            p = os.path.join(ART, w["file"])
            n = os.path.getsize(p) // 4
            assert n == int(np.prod(w["shape"])), w


def test_dataset_files_match_counts(manifest):
    for split in ("train", "test", "ice"):
        d = manifest["dataset"][split]
        ip = os.path.join(ART, d["images"])
        n = d["count"]
        c, h, w = d["image_shape"]
        assert os.path.getsize(ip) == n * c * h * w * 4
        lp = os.path.join(ART, d["labels"])
        assert os.path.getsize(lp) == n * 4
        labels = np.fromfile(lp, dtype="<i4")
        assert labels.min() >= 0 and labels.max() < 10


def test_cs_curve_well_formed(manifest):
    cs = manifest["cs_curve"]
    n = len(cs["norm"])
    assert n == len(cs["layer_names"]) == 18
    assert min(cs["norm"]) == 0.0 and max(cs["norm"]) == 1.0
    for c in cs["candidates"]:
        assert 0 < c < n - 1


def test_candidates_are_local_maxima(manifest):
    cs = manifest["cs_curve"]["norm"]
    for c in manifest["cs_curve"]["candidates"]:
        assert cs[c] > cs[c - 1] and cs[c] >= cs[c + 1]


def test_split_eval_rows(manifest):
    for r in manifest["split_eval"]:
        assert 0.0 <= r["accuracy"] <= 1.0
        assert r["latent_bytes_per_image"] * 2 == \
            r["feature_bytes_per_image"]


def test_executables_cover_candidates(manifest):
    names = {e["name"] for e in manifest["executables"]}
    assert "full_fwd_b1" in names and "full_fwd_b16" in names
    for li in manifest["cs_curve"]["candidates"]:
        for k in (f"head_L{li}_b1", f"tail_L{li}_b1",
                  f"head_L{li}_b16", f"tail_L{li}_b16"):
            assert k in names, k


def test_fixture_logits_shape(manifest):
    f = manifest["fixtures"]["test16_logits"]
    p = os.path.join(ART, f["file"])
    n = os.path.getsize(p) // 4
    assert n == int(np.prod(f["shape"]))
