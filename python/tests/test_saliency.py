"""Grad-CAM / CS curve: kernel-vs-jnp equivalence, curve properties."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model as M
from compile import saliency as S

CFG = M.ModelConfig(width_mult=0.125)
PARAMS = M.init_params(CFG, seed=0)
RNG = np.random.default_rng(1)
X = jnp.asarray(RNG.uniform(0, 1, (4, 3, 32, 32)), jnp.float32)
Y = jnp.asarray(RNG.integers(0, 10, 4), jnp.int32)


def test_cs_layer_kernel_matches_jnp():
    for li in (0, 5, 11, 17):
        a = S.cs_layer_fn(CFG, li, use_kernel=True)(PARAMS, X, Y)
        b = S.cs_layer_fn(CFG, li, use_kernel=False)(PARAMS, X, Y)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6,
                                   err_msg=f"layer {li}")


def test_cs_values_nonnegative():
    for li in (3, 9, 15):
        v = S.cs_layer_fn(CFG, li, use_kernel=False)(PARAMS, X, Y)
        assert float(jnp.min(v)) >= 0.0


def test_cs_curve_shape_and_normalization():
    imgs = np.asarray(X)
    labels = np.asarray(Y)
    norm, raw = S.cs_curve(CFG, PARAMS, imgs, labels, batch=4,
                           layers=[0, 5, 9, 17])
    assert len(norm) == 4 and len(raw) == 4
    assert norm.min() == 0.0 and norm.max() == 1.0


def test_local_maxima_simple():
    curve = [0.0, 0.5, 0.2, 0.8, 0.3, 0.9, 0.1]
    assert S.local_maxima(curve, min_layer=1) == [1, 3, 5]
    assert S.local_maxima(curve, min_layer=2) == [3, 5]


def test_local_maxima_excludes_endpoints():
    curve = [1.0, 0.5, 0.2, 0.1, 0.9]
    assert S.local_maxima(curve, min_layer=1) == []


def test_local_maxima_plateau_takes_first():
    curve = [0.0, 0.2, 0.8, 0.8, 0.1, 0.0]
    assert S.local_maxima(curve, min_layer=1) == [2]


def test_local_maxima_respects_min_layer():
    curve = [0.0, 0.9, 0.1, 0.8, 0.1, 0.0]
    assert S.local_maxima(curve, min_layer=3) == [3]
