"""Bottleneck AE (Eqs. 3-4): shapes, compression rate, trainability."""

import functools

import jax.numpy as jnp
import numpy as np

from compile import bottleneck as B
from compile import model as M
from compile import train as T

CFG = M.ModelConfig(width_mult=0.125)
PARAMS = M.init_params(CFG, seed=0)
RNG = np.random.default_rng(2)
X = jnp.asarray(RNG.uniform(0, 1, (4, 3, 32, 32)), jnp.float32)
Y = jnp.asarray(RNG.integers(0, 10, 4), jnp.int32)
LI = 9  # block3_pool


def _full_params(li=LI):
    p = dict(PARAMS)
    p.update(B.init_ae_params(CFG, li, seed=0))
    return p


def test_latent_is_half_channels():
    for li in (5, 9, 13, 15):
        c, h, w = CFG.feature_shape(li)
        zc, zh, zw = B.latent_shape(CFG, li)
        assert (zc, zh, zw) == (c // 2, h, w)
        # 50% compression rate on bytes
        assert zc * zh * zw * 4 * 2 == c * h * w * 4


def test_head_tail_shapes():
    p = _full_params()
    z = B.head_forward(CFG, p, X, LI)
    assert z.shape == (4,) + B.latent_shape(CFG, LI)
    logits = B.tail_forward(CFG, p, z, LI)
    assert logits.shape == (4, 10)


def test_split_forward_composes_head_tail():
    p = _full_params()
    via_split = B.split_forward(CFG, p, X, LI)
    via_ht = B.tail_forward(CFG, p, B.head_forward(CFG, p, X, LI), LI)
    np.testing.assert_allclose(via_split, via_ht, rtol=1e-6)


def test_ae_loss_decreases_with_training():
    p = _full_params()
    loss_fn = functools.partial(B.loss_ae, CFG, LI)
    l0 = float(loss_fn(p, X, Y))
    step = T.make_train_step(loss_fn, 1e-3,
                             trainable=set(B.ae_param_names(LI)))
    st = T.adam_init(p)
    for _ in range(30):
        p, st, l = step(p, st, X, Y)
    assert float(l) < l0


def test_ae_training_freezes_backbone():
    p = _full_params()
    loss_fn = functools.partial(B.loss_ae, CFG, LI)
    step = T.make_train_step(loss_fn, 1e-3,
                             trainable=set(B.ae_param_names(LI)))
    st = T.adam_init(p)
    p2, st, _ = step(p, st, X, Y)
    for k in M.param_names(CFG):
        np.testing.assert_array_equal(p[k], p2[k], err_msg=k)
    changed = any(
        not np.array_equal(p[k], p2[k]) for k in B.ae_param_names(LI))
    assert changed


def test_finetune_loss_finite():
    p = _full_params()
    l = float(B.loss_finetune(CFG, LI, p, X, Y))
    assert np.isfinite(l) and l > 0


def test_split_accuracy_bounds():
    p = _full_params()
    acc = B.split_accuracy(CFG, p, LI, np.asarray(X), np.asarray(Y), batch=2)
    assert 0.0 <= acc <= 1.0
