"""Synthetic dataset generator: determinism, ranges, class separability."""

import os

import numpy as np

from compile import dataset as D


def test_shapes_and_ranges():
    imgs, labels = D.make_dataset(32, seed=0)
    assert imgs.shape == (32, 3, 32, 32) and imgs.dtype == np.float32
    assert labels.shape == (32,) and labels.dtype == np.int32
    assert imgs.min() >= 0.0 and imgs.max() <= 1.0
    assert labels.min() >= 0 and labels.max() < D.NUM_CLASSES


def test_deterministic():
    a, la = D.make_dataset(16, seed=42)
    b, lb = D.make_dataset(16, seed=42)
    np.testing.assert_array_equal(a, b)
    np.testing.assert_array_equal(la, lb)


def test_seed_changes_data():
    a, _ = D.make_dataset(16, seed=1)
    b, _ = D.make_dataset(16, seed=2)
    assert not np.array_equal(a, b)


def test_all_classes_renderable():
    rng = np.random.default_rng(0)
    for cls in range(D.NUM_CLASSES):
        mask = D._render_mask(cls, 16, 16, 7.0, rng)
        assert mask.any(), D.CLASS_NAMES[cls]
        assert mask.shape == (D.IMG_SIZE, D.IMG_SIZE)


def test_ice_variant_differs_from_plain():
    a, _ = D.make_dataset(8, seed=5, ice=False)
    b, _ = D.make_dataset(8, seed=5, ice=True)
    assert not np.array_equal(a, b)


def test_classes_linearly_separable_enough():
    """A trivial nearest-class-mean classifier should beat chance by a lot —
    guards against a generator bug that makes classes indistinguishable."""
    imgs, labels = D.make_dataset(400, seed=3)
    feats = imgs.reshape(400, -1)
    means = np.stack([feats[labels == c].mean(axis=0)
                      for c in range(D.NUM_CLASSES)])
    pred = np.argmin(
        ((feats[:, None, :] - means[None]) ** 2).sum(-1), axis=1)
    acc = (pred == labels).mean()
    assert acc > 0.3, acc  # chance is 0.1


def test_save_roundtrip(tmp_path):
    imgs, labels = D.make_dataset(4, seed=0)
    fp = tmp_path / "x.bin"
    D.save_tensor_f32(fp, imgs)
    back = np.fromfile(fp, dtype="<f4").reshape(imgs.shape)
    np.testing.assert_array_equal(back, imgs)
    lp = tmp_path / "y.bin"
    D.save_tensor_i32(lp, labels)
    lback = np.fromfile(lp, dtype="<i4")
    np.testing.assert_array_equal(lback, labels)
