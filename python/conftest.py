"""Make `import compile...` work when pytest is invoked from the repo root
(`pytest python/tests/`) as well as from python/ (`pytest tests/`), and
skip test modules whose optional heavy dependencies (JAX, hypothesis) are
not installed — CI runs the suite on a bare interpreter.

The skip rule is general, not a hand-maintained list: any test module
whose source imports an unavailable optional dependency is ignored at
collection time, so new JAX-dependent test files need no registration.
"""

import importlib.util
import os
import re
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
sys.path.insert(0, _HERE)

_OPTIONAL_DEPS = ["jax", "hypothesis"]
_MISSING = [d for d in _OPTIONAL_DEPS
            if importlib.util.find_spec(d) is None]

_IMPORT_RE = re.compile(
    r"^\s*(?:import|from)\s+(" + "|".join(_OPTIONAL_DEPS) + r")\b",
    re.MULTILINE,
)


def _needs_missing_dep(path: str) -> bool:
    try:
        with open(path, encoding="utf-8") as f:
            src = f.read()
    except OSError:
        return False
    return any(m.group(1) in _MISSING for m in _IMPORT_RE.finditer(src))


collect_ignore = []
if _MISSING:
    tests_dir = os.path.join(_HERE, "tests")
    if os.path.isdir(tests_dir):
        collect_ignore = [
            os.path.join("tests", name)
            for name in sorted(os.listdir(tests_dir))
            if name.endswith(".py")
            and _needs_missing_dep(os.path.join(tests_dir, name))
        ]
