"""Pure-jnp oracles for the Pallas kernels (pytest/hypothesis ground truth)."""

import jax
import jax.numpy as jnp


@jax.jit
def matmul_ref(x, y):
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


@jax.jit
def saliency_ref(f, alpha):
    """f: [B, Z, H, W], alpha: [B, Z] -> [B]."""
    cam = jnp.einsum("bzhw,bz->bhw", f, alpha)
    cam = jnp.maximum(cam, 0.0)
    return jnp.mean(cam, axis=(1, 2))
