from . import matmul, ref, saliency  # noqa: F401
