"""L1 Pallas kernel: fused Grad-CAM saliency reduction (paper Eqs. 1-2).

Given a feature map F [B, Z, H, W] and the per-channel importance weights
alpha [B, Z] (alpha is the spatially-pooled gradient dy_c/dF, Eq. 1), the
class activation map is L = ReLU(sum_z alpha_z * F_z) (Eq. 2) and the
per-input Cumulative Saliency contribution is the spatial mean of L.

This kernel fuses weighted-channel-sum -> ReLU -> spatial mean into a single
VMEM-resident pass per batch element: the [Z, H, W] block is read once from
HBM, reduced in registers/VMEM, and a single scalar per input is written
back — an O(Z·H·W) -> O(1) reduction with no intermediate activation-map
round-trip, which is the paper's per-layer hot loop when sweeping all 18
feature layers over the test set.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _saliency_kernel(f_ref, a_ref, o_ref):
    f = f_ref[...]           # [1, Z, H, W]
    a = a_ref[...]           # [1, Z]
    cam = jnp.sum(f * a[:, :, None, None], axis=1)   # [1, H, W]
    cam = jnp.maximum(cam, 0.0)                      # ReLU (Eq. 2)
    o_ref[...] = jnp.mean(cam, axis=(1, 2))          # spatial mean -> CS_j


@jax.jit
def saliency_reduce(f, alpha):
    """f: [B, Z, H, W] f32, alpha: [B, Z] f32 -> cs: [B] f32."""
    b, z, h, w = f.shape
    assert alpha.shape == (b, z), (f.shape, alpha.shape)
    return pl.pallas_call(
        _saliency_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, z, h, w), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, z), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=True,
    )(f, alpha)
