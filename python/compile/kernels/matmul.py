"""L1 Pallas kernel: tiled matmul — the framework's compute hot-spot.

Convolution dominates the mult-adds of every VGG variant (Table II of the
paper: 247.74 G mult-adds, >95 % of which are the 13 conv layers). The L2
model lowers convolution as im2col × weight matmul, and this kernel is that
matmul.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid is
(M/bm, N/bn, K/bk) with K innermost so the (bm, bn) accumulator tile stays
resident in VMEM across the K loop — the TPU analogue of a CUDA
threadblock's shared-memory K-loop. Default blocks 128×128×128 keep the
working set at (bm·bk + bk·bn + bm·bn)·4 B = 192 KiB ≪ 16 MiB VMEM and feed
the 128×128 MXU systolic array with full tiles.

`interpret=True` is mandatory here: the CPU PJRT plugin cannot execute the
Mosaic custom-call a real TPU lowering would emit. Numerics are identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k):
    """One (bm, bn) output tile; grid axis 2 walks the K dimension."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _ceil_to(v, m):
    return (v + m - 1) // m * m


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, bm=128, bn=128, bk=128):
    """Pallas tiled matmul with automatic padding to block multiples.

    x: [M, K] f32, y: [K, N] f32 -> [M, N] f32.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm = min(bm, _ceil_to(m, 8))
    bn = min(bn, _ceil_to(n, 8))
    bk = min(bk, _ceil_to(k, 8))
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)))
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


def vmem_bytes(bm=128, bn=128, bk=128):
    """Analytic VMEM working set of one grid step (for DESIGN.md §Perf)."""
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization(m, k, n, bm=128, bn=128, bk=128):
    """Fraction of MXU issue slots doing useful work = useful MACs over
    MACs issued for the padded problem. 1.0 when all dims are block
    multiples; drops with padding waste."""
    mp, kp, np_ = _ceil_to(m, bm), _ceil_to(k, bk), _ceil_to(n, bn)
    return (m * k * n) / float(mp * kp * np_)
