"""AOT pipeline: train everything, compute the CS curve, evaluate splits,
and export every HLO artifact + weight file + the manifest the Rust
coordinator consumes.

Run once by `make artifacts` (python is never on the request path):

  cd python && python -m compile.aot --outdir ../artifacts

Stages (each checkpointed under artifacts/checkpoints/ so reruns are cheap):
  1. synthetic datasets (train / test / ICE-Lab stream)       -> dataset/
  2. base VGG16-slim training (Adam, lr 5e-3 — paper Sec. V)  -> weights/base/
  3. Grad-CAM Cumulative Saliency curve (Eqs. 1-2)            -> manifest
  4. per-layer split evaluation: bottleneck AE (Eq. 3, lr 5e-4)
     + end-to-end fine-tune (Eq. 4)                           -> manifest
  5. HLO exports: full fwd (jnp + Pallas variants), head/tail per
     candidate split, per-layer Grad-CAM reducers             -> *.hlo.txt
  6. fixtures for the Rust integration tests                  -> fixtures/
  7. manifest.json
"""

import argparse
import functools
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import bottleneck as B
from . import dataset as D
from . import model as M
from . import saliency as S
from . import train as T
from .hlo import export_fn

SEED = 7


# ---------------------------------------------------------------------------
# Checkpoint helpers
# ---------------------------------------------------------------------------

def _ckpt_path(outdir, name):
    return os.path.join(outdir, "checkpoints", name + ".npz")


def _save_params(outdir, name, params):
    os.makedirs(os.path.join(outdir, "checkpoints"), exist_ok=True)
    np.savez(_ckpt_path(outdir, name),
             **{k: np.asarray(v) for k, v in params.items()})


def _load_params(outdir, name):
    p = _ckpt_path(outdir, name)
    if not os.path.exists(p):
        return None
    with np.load(p) as z:
        return {k: jnp.asarray(z[k]) for k in z.files}


def _save_json(outdir, name, obj):
    with open(os.path.join(outdir, "checkpoints", name + ".json"), "w") as f:
        json.dump(obj, f)


def _load_json(outdir, name):
    p = os.path.join(outdir, "checkpoints", name + ".json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


# ---------------------------------------------------------------------------
# Stages
# ---------------------------------------------------------------------------

def stage_datasets(outdir, fast):
    ddir = os.path.join(outdir, "dataset")
    os.makedirs(ddir, exist_ok=True)
    sizes = {"train": 512 if fast else 4096,
             "test": 256 if fast else 1024,
             "ice": 128 if fast else 512}
    sets, meta = {}, {}
    for split, n in sizes.items():
        imgs, labels = D.make_dataset(n, seed=SEED + hash(split) % 1000,
                                      ice=(split == "ice"))
        D.save_tensor_f32(os.path.join(ddir, f"{split}_images.bin"), imgs)
        D.save_tensor_i32(os.path.join(ddir, f"{split}_labels.bin"), labels)
        sets[split] = (imgs, labels)
        meta[split] = {
            "images": f"dataset/{split}_images.bin",
            "labels": f"dataset/{split}_labels.bin",
            "count": n,
            "image_shape": [3, D.IMG_SIZE, D.IMG_SIZE],
        }
    meta["class_names"] = D.CLASS_NAMES
    return sets, meta


def stage_base_training(outdir, cfg, sets, fast):
    params = _load_params(outdir, "base")
    meta = _load_json(outdir, "base_meta")
    if params is not None and meta is not None:
        print("[base] checkpoint hit", flush=True)
        return params, meta
    t0 = time.time()
    imgs, labels = sets["train"]
    steps = 120 if fast else 900
    params = M.init_params(cfg, seed=SEED)
    loss_fn = functools.partial(M.loss_ce, cfg)
    params, losses = T.train(loss_fn, params, imgs, labels, steps=steps,
                             batch=96, lr=5e-4 if fast else 1e-3,
                             seed=SEED, log_every=100, tag="base")
    acc_fn = jax.jit(functools.partial(M.accuracy, cfg))
    acc = T.eval_accuracy(acc_fn, params, *sets["test"])
    acc_ice = T.eval_accuracy(acc_fn, params, *sets["ice"])
    meta = {"steps": steps, "test_accuracy": acc, "ice_accuracy": acc_ice,
            "final_loss": losses[-1], "train_seconds": time.time() - t0}
    print(f"[base] test acc {acc:.3f}, ice acc {acc_ice:.3f} "
          f"({meta['train_seconds']:.0f}s)", flush=True)
    _save_params(outdir, "base", params)
    _save_json(outdir, "base_meta", meta)
    return params, meta


def stage_lite_training(outdir, lite_cfg, sets, fast):
    """Local-computing baseline: a lightweight model small enough for the
    sensing device (the paper's MobileNet stand-in). Lower accuracy than
    the full model — the LC/RC/SC trade-off of Sec. II."""
    params = _load_params(outdir, "lite")
    meta = _load_json(outdir, "lite_meta")
    if params is not None and meta is not None:
        print("[lite] checkpoint hit", flush=True)
        return params, meta
    t0 = time.time()
    imgs, labels = sets["train"]
    steps = 80 if fast else 500
    params = M.init_params(lite_cfg, seed=SEED + 1)
    loss_fn = functools.partial(M.loss_ce, lite_cfg)
    params, losses = T.train(loss_fn, params, imgs, labels, steps=steps,
                             batch=96, lr=1e-3, seed=SEED + 1,
                             log_every=200, tag="lite")
    acc_fn = jax.jit(functools.partial(M.accuracy, lite_cfg))
    acc = T.eval_accuracy(acc_fn, params, *sets["test"])
    meta = {"steps": steps, "test_accuracy": acc,
            "train_seconds": time.time() - t0}
    print(f"[lite] test acc {acc:.3f} ({meta['train_seconds']:.0f}s)",
          flush=True)
    _save_params(outdir, "lite", params)
    _save_json(outdir, "lite_meta", meta)
    return params, meta


def stage_cs_curve(outdir, cfg, params, sets, fast):
    cached = _load_json(outdir, "cs_curve")
    if cached is not None:
        print("[cs] checkpoint hit", flush=True)
        return cached
    t0 = time.time()
    imgs, labels = sets["test"]
    n = 128 if fast else 512
    norm, raw = S.cs_curve(cfg, params, imgs[:n], labels[:n], batch=64)
    cands = S.local_maxima(norm)
    out = {"norm": [float(v) for v in norm], "raw": [float(v) for v in raw],
           "candidates": [int(c) for c in cands],
           "layer_names": M.VGG16_LAYER_NAMES,
           "seconds": time.time() - t0}
    print(f"[cs] candidates {cands} ({out['seconds']:.0f}s)", flush=True)
    _save_json(outdir, "cs_curve", out)
    return out


def stage_split_eval(outdir, cfg, params, sets, layers, fast):
    """Per-layer bottleneck training (Eq. 3) + fine-tune (Eq. 4) + accuracy.

    Returns (eval rows, {layer: fine-tuned full param dict}).
    """
    rows = _load_json(outdir, "split_eval") or []
    done = {r["layer"] for r in rows}
    split_params = {}
    imgs, labels = sets["train"]
    ae_steps = 60 if fast else 300
    ft_steps = 40 if fast else 200
    for li in layers:
        name = f"split_L{li}"
        if li in done:
            p = _load_params(outdir, name)
            if p is not None:
                split_params[li] = p
                continue
        t0 = time.time()
        full = dict(params)
        full.update(B.init_ae_params(cfg, li, seed=SEED))
        trainable = set(B.ae_param_names(li))
        # Eq. 3: train the sole bottleneck, backbone frozen.
        full, _ = T.train(functools.partial(B.loss_ae, cfg, li), full,
                          imgs, labels, steps=ae_steps, batch=48, lr=5e-4,
                          seed=SEED + li, trainable=trainable, tag=f"ae{li}")
        # Eq. 4: fine-tune end-to-end.
        full, _ = T.train(functools.partial(B.loss_finetune, cfg, li), full,
                          imgs, labels, steps=ft_steps, batch=48, lr=3e-4,
                          seed=SEED + li, tag=f"ft{li}")
        acc = B.split_accuracy(cfg, full, li, *sets["test"])
        zshape = B.latent_shape(cfg, li)
        rows = [r for r in rows if r["layer"] != li]
        rows.append({
            "layer": li,
            "layer_name": M.VGG16_LAYER_NAMES[li],
            "accuracy": acc,
            "latent_shape": list(zshape),
            "latent_bytes_per_image": int(np.prod(zshape)) * 4,
            "feature_bytes_per_image":
                int(np.prod(cfg.feature_shape(li))) * 4,
            "seconds": time.time() - t0,
        })
        rows.sort(key=lambda r: r["layer"])
        split_params[li] = full
        _save_params(outdir, name, full)
        _save_json(outdir, "split_eval", rows)
        print(f"[split L{li} {M.VGG16_LAYER_NAMES[li]}] acc {acc:.3f} "
              f"({time.time() - t0:.0f}s)", flush=True)
    return rows, split_params


# ---------------------------------------------------------------------------
# Export helpers
# ---------------------------------------------------------------------------

def _write_weights(outdir, setname, named):
    wdir = os.path.join(outdir, "weights", setname)
    os.makedirs(wdir, exist_ok=True)
    entries = []
    for name, arr in named:
        rel = f"weights/{setname}/{name}.bin"
        D.save_tensor_f32(os.path.join(outdir, rel), np.asarray(arr))
        entries.append({"name": name, "file": rel,
                        "shape": list(arr.shape)})
    return entries


def _flat_params(cfg, params, extra_names=()):
    names = M.param_names(cfg) + list(extra_names)
    return [(n, params[n]) for n in names]


def _export(outdir, name, fn, inputs, weight_entries, weight_arrays,
            outputs, kind, extra=None):
    """Lower fn(x..., *weights) and record a manifest executable entry."""
    rel = name + ".hlo.txt"
    example = [a for _, a in inputs] + weight_arrays
    nbytes = export_fn(fn, example, os.path.join(outdir, rel))
    entry = {
        "name": name, "hlo": rel, "kind": kind,
        "inputs": [{"name": n, "shape": list(a.shape),
                    "dtype": str(a.dtype)} for n, a in inputs],
        "weights": weight_entries,
        "outputs": outputs,
        "hlo_chars": nbytes,
    }
    if extra:
        entry.update(extra)
    print(f"[export] {rel} ({nbytes} chars)", flush=True)
    return entry


def stage_export(outdir, cfg, params, split_params, split_eval_rows,
                 candidates, sets, fast, lite=None):
    execs = []
    base_named = _flat_params(cfg, params)
    base_entries = _write_weights(outdir, "base", base_named)
    base_arrays = [a for _, a in base_named]
    x1 = jnp.zeros((1, 3, cfg.img_size, cfg.img_size), jnp.float32)
    x16 = jnp.zeros((16, 3, cfg.img_size, cfg.img_size), jnp.float32)

    def full_fn(x, *ws):
        p = {n: w for (n, _), w in zip(base_named, ws)}
        return (M.forward(cfg, p, x),)

    for bs, xb in (("b1", x1), ("b16", x16)):
        execs.append(_export(
            outdir, f"full_fwd_{bs}", full_fn, [("x", xb)], base_entries,
            base_arrays,
            [{"name": "logits", "shape": [xb.shape[0], cfg.num_classes]}],
            kind="full", extra={"batch": int(xb.shape[0])}))

    # Local-computing (LC) lightweight model.
    if lite is not None:
        lite_cfg, lite_params = lite
        lite_named = [(n, lite_params[n]) for n in M.param_names(lite_cfg)]
        lite_entries = _write_weights(outdir, "lite", lite_named)
        lite_arrays = [a for _, a in lite_named]

        def lite_fn(x, *ws):
            p = {n: w for (n, _), w in zip(lite_named, ws)}
            return (M.forward(lite_cfg, p, x),)

        for bs, xb in (("b1", x1), ("b16", x16)):
            execs.append(_export(
                outdir, f"full_fwd_lite_{bs}", lite_fn, [("x", xb)],
                lite_entries, lite_arrays,
                [{"name": "logits",
                  "shape": [xb.shape[0], cfg.num_classes]}],
                kind="full_lite", extra={"batch": int(xb.shape[0])}))

    # Pallas-conv variant of the same forward (numerics equality is a rust
    # integration test; pallas interpret lowering is large, keep batch small)
    pcfg = M.ModelConfig(cfg.width_mult, cfg.num_classes, cfg.img_size,
                         cfg.hidden, use_pallas=True)
    x4 = jnp.zeros((4, 3, cfg.img_size, cfg.img_size), jnp.float32)

    def full_pallas_fn(x, *ws):
        p = {n: w for (n, _), w in zip(base_named, ws)}
        return (M.forward(pcfg, p, x),)

    execs.append(_export(
        outdir, "full_fwd_pallas_b4", full_pallas_fn, [("x", x4)],
        base_entries, base_arrays,
        [{"name": "logits", "shape": [4, cfg.num_classes]}],
        kind="full_pallas", extra={"batch": 4}))

    # Head/tail per candidate split (fine-tuned weight set per split).
    for li in candidates:
        full = split_params[li]
        named = _flat_params(cfg, full, extra_names=B.ae_param_names(li))
        entries = _write_weights(outdir, f"split_L{li}", named)
        arrays = [a for _, a in named]
        zc, zh, zw = B.latent_shape(cfg, li)

        def head_fn(x, *ws, _li=li, _named=named):
            p = {n: w for (n, _), w in zip(_named, ws)}
            return (B.head_forward(cfg, p, x, _li),)

        def tail_fn(z, *ws, _li=li, _named=named):
            p = {n: w for (n, _), w in zip(_named, ws)}
            return (B.tail_forward(cfg, p, z, _li),)

        for bs, n in (("b1", 1), ("b16", 16)):
            xb = jnp.zeros((n, 3, cfg.img_size, cfg.img_size), jnp.float32)
            zb = jnp.zeros((n, zc, zh, zw), jnp.float32)
            execs.append(_export(
                outdir, f"head_L{li}_{bs}", head_fn, [("x", xb)], entries,
                arrays, [{"name": "latent", "shape": [n, zc, zh, zw]}],
                kind="head",
                extra={"batch": n, "split_layer": li,
                       "latent_shape": [zc, zh, zw]}))
            execs.append(_export(
                outdir, f"tail_L{li}_{bs}", tail_fn, [("z", zb)], entries,
                arrays, [{"name": "logits", "shape": [n, cfg.num_classes]}],
                kind="tail",
                extra={"batch": n, "split_layer": li,
                       "latent_shape": [zc, zh, zw]}))

    # Per-layer Grad-CAM CS reducers (L1 pallas saliency kernel inside).
    y16 = jnp.zeros((16,), jnp.int32)
    gradcam_layers = (range(2, M.NUM_FEATURE_LAYERS, 4) if fast
                      else range(M.NUM_FEATURE_LAYERS))
    for li in gradcam_layers:
        fn = S.cs_layer_fn(cfg, li, use_kernel=True)

        def gc_fn(x, y, *ws, _fn=fn):
            p = {n: w for (n, _), w in zip(base_named, ws)}
            return (_fn(p, x, y),)

        execs.append(_export(
            outdir, f"gradcam_L{li}_b16", gc_fn,
            [("x", x16), ("y", y16)], base_entries, base_arrays,
            [{"name": "cs", "shape": [16]}], kind="gradcam",
            extra={"batch": 16, "layer": li,
                   "layer_name": M.VGG16_LAYER_NAMES[li]}))
    return execs


def stage_fixtures(outdir, cfg, params, sets):
    """Golden outputs for the Rust integration tests."""
    fdir = os.path.join(outdir, "fixtures")
    os.makedirs(fdir, exist_ok=True)
    imgs, labels = sets["test"]
    x = jnp.asarray(imgs[:16])
    logits = np.asarray(M.forward(cfg, params, x))
    D.save_tensor_f32(os.path.join(fdir, "test16_logits.bin"), logits)
    return {
        "test16_logits": {"file": "fixtures/test16_logits.bin",
                          "shape": list(logits.shape)},
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--fast", action="store_true",
                    help="reduced steps/sizes (CI / pytest)")
    ap.add_argument("--out", default=None, help=argparse.SUPPRESS)  # legacy
    args = ap.parse_args()
    outdir = args.outdir
    os.makedirs(outdir, exist_ok=True)
    t0 = time.time()

    cfg = M.ModelConfig(width_mult=0.125, num_classes=10, img_size=32,
                        hidden=64)

    lite_cfg = M.ModelConfig(width_mult=0.0625, num_classes=10, img_size=32,
                             hidden=48)

    sets, dataset_meta = stage_datasets(outdir, args.fast)
    params, base_meta = stage_base_training(outdir, cfg, sets, args.fast)
    lite_params, lite_meta = stage_lite_training(outdir, lite_cfg, sets,
                                                 args.fast)
    cs = stage_cs_curve(outdir, cfg, params, sets, args.fast)

    candidates = cs["candidates"]
    # Export head/tail for the union of our CS candidates and the paper's
    # canonical Fig. 2 split set {5, 9, 11, 13, 15} (the Fig. 3 benches
    # simulate splits at layers 11 and 15 exactly as the paper does).
    paper_splits = [5, 9, 11, 13, 15]
    export_splits = sorted(set(candidates) | set(paper_splits))
    # Fig. 2 needs the split-accuracy trace for non-candidate layers too.
    trace_layers = (sorted(set(candidates))[:2] if args.fast
                    else list(range(1, M.NUM_FEATURE_LAYERS - 1)))
    eval_layers = sorted(set(trace_layers) | set(export_splits))
    split_rows, split_params = stage_split_eval(
        outdir, cfg, params, sets, eval_layers, args.fast)

    execs = stage_export(outdir, cfg, params, split_params, split_rows,
                         export_splits if not args.fast else candidates,
                         sets, args.fast, lite=(lite_cfg, lite_params))
    fixtures = stage_fixtures(outdir, cfg, params, sets)

    manifest = {
        "version": 1,
        "seed": SEED,
        "fast": bool(args.fast),
        "model": {
            "arch": "vgg16-slim",
            "width_mult": cfg.width_mult,
            "num_classes": cfg.num_classes,
            "img_size": cfg.img_size,
            "hidden": cfg.hidden,
            "layer_names": M.VGG16_LAYER_NAMES,
            "feature_shapes": [list(cfg.feature_shape(i))
                               for i in range(M.NUM_FEATURE_LAYERS)],
            "total_params": int(M.total_params(cfg)),
            "base_test_accuracy": base_meta["test_accuracy"],
            "ice_accuracy": base_meta["ice_accuracy"],
        },
        "lite_model": {
            "width_mult": lite_cfg.width_mult,
            "hidden": lite_cfg.hidden,
            "total_params": int(M.total_params(lite_cfg)),
            "test_accuracy": lite_meta["test_accuracy"],
        },
        "dataset": dataset_meta,
        "cs_curve": cs,
        "split_eval": split_rows,
        "executables": execs,
        "fixtures": fixtures,
    }
    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] done in {time.time() - t0:.0f}s -> {outdir}/manifest.json",
          flush=True)


if __name__ == "__main__":
    main()
