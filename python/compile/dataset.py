"""Synthetic "toys" dataset generator (build-time only).

The paper evaluates on CIFAR10 ("a placeholder for bigger datasets") and on
images of children's toys on a conveyor belt in the ICE Laboratory (Verona).
Neither is available offline, so we substitute a deterministic, procedurally
generated shape-classification dataset that preserves the properties the
framework actually exercises:

* a learnable 10-class image classification task (accuracy well above chance
  after a short training run);
* intermediate feature maps whose corruption (UDP packet loss) measurably
  degrades accuracy;
* an "ICE-Lab stream" variant — same classes rendered over a conveyor-belt
  background texture with a different seed — standing in for the lab capture.

Everything is seeded: `make artifacts` is hermetic.
"""

import numpy as np

IMG_SIZE = 32
NUM_CLASSES = 10
CLASS_NAMES = [
    "circle", "square", "triangle", "cross", "ring",
    "hbar", "vbar", "diamond", "checker", "dotgrid",
]


def _coords(size):
    y, x = np.mgrid[0:size, 0:size].astype(np.float32)
    return x, y


def _render_mask(cls, cx, cy, r, rng, size=IMG_SIZE):
    """Binary mask for one shape instance. r is the characteristic radius."""
    x, y = _coords(size)
    dx, dy = x - cx, y - cy
    if cls == 0:      # circle
        return (dx * dx + dy * dy) <= r * r
    if cls == 1:      # square
        return (np.abs(dx) <= r) & (np.abs(dy) <= r)
    if cls == 2:      # triangle (upward)
        return (dy <= r) & (dy >= -r) & (np.abs(dx) <= (dy + r) * 0.6)
    if cls == 3:      # cross
        return ((np.abs(dx) <= r * 0.35) & (np.abs(dy) <= r)) | (
            (np.abs(dy) <= r * 0.35) & (np.abs(dx) <= r))
    if cls == 4:      # ring
        d2 = dx * dx + dy * dy
        return (d2 <= r * r) & (d2 >= (0.55 * r) ** 2)
    if cls == 5:      # horizontal bar
        return (np.abs(dy) <= r * 0.35) & (np.abs(dx) <= r * 1.2)
    if cls == 6:      # vertical bar
        return (np.abs(dx) <= r * 0.35) & (np.abs(dy) <= r * 1.2)
    if cls == 7:      # diamond
        return (np.abs(dx) + np.abs(dy)) <= r * 1.2
    if cls == 8:      # checker 2x2
        cell = np.maximum(r * 0.5, 1.0)
        par = (np.floor(dx / cell) + np.floor(dy / cell)) % 2 == 0
        return par & (np.abs(dx) <= r) & (np.abs(dy) <= r)
    if cls == 9:      # dot grid 3x3
        mask = np.zeros((size, size), dtype=bool)
        for gy in (-1, 0, 1):
            for gx in (-1, 0, 1):
                ddx, ddy = dx - gx * r * 0.8, dy - gy * r * 0.8
                mask |= (ddx * ddx + ddy * ddy) <= (r * 0.28) ** 2
        return mask
    raise ValueError(cls)


def _conveyor_background(rng, size=IMG_SIZE):
    """Dark conveyor-belt texture: horizontal slats + roller highlights."""
    x, y = _coords(size)
    phase = rng.uniform(0, 2 * np.pi)
    slats = 0.12 + 0.05 * np.sin(2 * np.pi * y / 6.0 + phase)
    img = np.stack([slats, slats, slats * 1.05], axis=0)
    img += rng.normal(0, 0.02, img.shape).astype(np.float32)
    return img.astype(np.float32)


def _plain_background(rng, size=IMG_SIZE):
    base = rng.uniform(0.0, 0.35, size=(3, 1, 1)).astype(np.float32)
    img = np.broadcast_to(base, (3, size, size)).copy()
    img += rng.normal(0, 0.02, img.shape).astype(np.float32)
    return img.astype(np.float32)


def make_dataset(n, seed, ice=False):
    """Returns (images [n,3,32,32] float32 in [0,1], labels [n] int32).

    Deliberately non-trivial: each image carries a smaller *distractor*
    shape of a random other class, the target colour range overlaps the
    background, and pixel noise is substantial. A slim VGG lands around
    85-95% — enough headroom that split/bottleneck injection and UDP
    corruption produce measurable accuracy deltas (the quantities the
    paper's figures are about), and the softmax never saturates (Grad-CAM
    needs live gradients).
    """
    rng = np.random.default_rng(seed)
    images = np.empty((n, 3, IMG_SIZE, IMG_SIZE), dtype=np.float32)
    labels = rng.integers(0, NUM_CLASSES, size=n).astype(np.int32)
    for i in range(n):
        cls = int(labels[i])
        bg = _conveyor_background(rng) if ice else _plain_background(rng)
        img = bg
        # distractor: a smaller shape of a different class
        dcls = int((cls + rng.integers(1, NUM_CLASSES)) % NUM_CLASSES)
        dcx = rng.uniform(5, IMG_SIZE - 5)
        dcy = rng.uniform(5, IMG_SIZE - 5)
        dmask = _render_mask(dcls, dcx, dcy, rng.uniform(2.5, 4.0), rng)
        dcolor = rng.uniform(0.35, 0.8, size=3).astype(np.float32)
        for c in range(3):
            img[c][dmask] = dcolor[c]
        # target shape (drawn last, occludes the distractor)
        cx = rng.uniform(10, IMG_SIZE - 10)
        cy = rng.uniform(10, IMG_SIZE - 10)
        r = rng.uniform(4.5, 8.0)
        mask = _render_mask(cls, cx, cy, r, rng)
        color = rng.uniform(0.4, 1.0, size=3).astype(np.float32)
        for c in range(3):
            img[c][mask] = color[c]
        img += rng.normal(0, 0.08, img.shape).astype(np.float32)
        images[i] = np.clip(img, 0.0, 1.0)
    return images, labels


def save_tensor_f32(path, arr):
    """Raw little-endian f32, C order. Shape is recorded in the manifest."""
    np.ascontiguousarray(arr, dtype="<f4").tofile(path)


def save_tensor_i32(path, arr):
    np.ascontiguousarray(arr, dtype="<i4").tofile(path)
