"""Lowering helper: jitted jax function -> HLO *text* artifact.

HLO text (not `.serialize()`d HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
bundled xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the HLO text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md and rust/src/runtime/.
"""

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True;
    the Rust side unwraps with to_tuple1)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_fn(fn, example_args, out_path):
    """Lower `fn` at the shapes/dtypes of `example_args`, write HLO text."""
    specs = [
        jax.ShapeDtypeStruct(a.shape, a.dtype) for a in example_args
    ]
    # keep_unused=True: the Rust runtime feeds (input, *all_weights)
    # positionally per the manifest; jit's default would silently drop
    # weights a particular head/tail slice doesn't touch and desynchronize
    # the calling convention ("supplied N buffers but expected M").
    lowered = jax.jit(fn, keep_unused=True).lower(*specs)
    text = to_hlo_text(lowered)
    with open(out_path, "w") as f:
        f.write(text)
    return len(text)
