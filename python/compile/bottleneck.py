"""Bottleneck autoencoder for split computing — paper Sec. III, Eqs. 3-4.

A split at feature layer T^i divides the network into:
  head   = feature layers 0..=i           (edge device)
  bottleneck = undercomplete AE: encoder (edge) + decoder (server)
  tail   = feature layers i+1..17 + classifier (server)

The encoder halves the channel dimension (the paper's "50% compression
rate"), so the transmitted latent is half the bytes of the raw feature map.

Training protocol (paper): (1) train the sole bottleneck with the
reconstruction loss Eq. 3, backbone frozen; (2) fine-tune the whole model
end-to-end with the MSE task loss Eq. 4.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def ae_param_names(layer_idx):
    p = f"ae{layer_idx}_"
    return [p + "enc_w", p + "enc_b", p + "dec_w", p + "dec_b"]


def latent_channels(cfg, layer_idx):
    c, _, _ = cfg.feature_shape(layer_idx)
    return max(c // 2, 1)


def latent_shape(cfg, layer_idx):
    c, h, w = cfg.feature_shape(layer_idx)
    return (latent_channels(cfg, layer_idx), h, w)


def init_ae_params(cfg, layer_idx, seed=0):
    rng = np.random.default_rng(seed + 1000 + layer_idx)
    c, _, _ = cfg.feature_shape(layer_idx)
    zc = latent_channels(cfg, layer_idx)
    p = f"ae{layer_idx}_"
    return {
        p + "enc_w": jnp.asarray(
            rng.normal(0, np.sqrt(2.0 / (c * 9)), (zc, c, 3, 3)), jnp.float32),
        p + "enc_b": jnp.zeros((zc,), jnp.float32),
        p + "dec_w": jnp.asarray(
            rng.normal(0, np.sqrt(2.0 / (zc * 9)), (c, zc, 3, 3)), jnp.float32),
        p + "dec_b": jnp.zeros((c,), jnp.float32),
    }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + b[None, :, None, None]


def encode(params, layer_idx, feat):
    """z_l = F(x) — executed at the edge (after the head)."""
    p = f"ae{layer_idx}_"
    return jax.nn.relu(_conv(feat, params[p + "enc_w"], params[p + "enc_b"]))


def decode(params, layer_idx, z):
    """x_bar = G(z_l) — executed at the server (before the tail)."""
    p = f"ae{layer_idx}_"
    return jax.nn.relu(_conv(z, params[p + "dec_w"], params[p + "dec_b"]))


def head_forward(cfg, params, x, layer_idx):
    """Edge side: input image -> compressed latent (what goes on the wire)."""
    feat = M.forward_features(cfg, params, x, upto=layer_idx)
    return encode(params, layer_idx, feat)


def tail_forward(cfg, params, z, layer_idx):
    """Server side: latent -> logits."""
    recon = decode(params, layer_idx, z)
    return M.forward_from(cfg, params, recon, layer_idx + 1)


def split_forward(cfg, params, x, layer_idx):
    """Full split model (head + bottleneck + tail), for training/eval."""
    return tail_forward(cfg, params, head_forward(cfg, params, x, layer_idx),
                        layer_idx)


def loss_ae(cfg, layer_idx, params, x, _y):
    """Paper Eq. 3: reconstruction MSE of the bottleneck at layer T^i."""
    feat = M.forward_features(cfg, params, x, upto=layer_idx)
    feat = jax.lax.stop_gradient(feat)     # backbone frozen
    recon = decode(params, layer_idx, encode(params, layer_idx, feat))
    return jnp.mean(jnp.sum((recon - feat) ** 2, axis=(1, 2, 3)))


def loss_finetune(cfg, layer_idx, params, x, y):
    """End-to-end fine-tune of the split model (paper Eq. 4 stage).

    Deviation from Eq. 4 as printed: the paper writes an MSE between model
    output and the ground-truth label. Applied literally to a CE-pretrained
    network, the MSE-to-onehot objective destroys the logit calibration
    before it can recover (measured: 0.98 -> 0.44 test accuracy at every
    split). We fine-tune with the cross-entropy the backbone was trained
    with, which is the standard split-computing practice the equation is
    gesturing at; see DESIGN.md.
    """
    logits = split_forward(cfg, params, x, layer_idx)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def split_accuracy(cfg, params, layer_idx, images, labels, batch=128):
    @jax.jit
    def acc(params, bx, by):
        logits = split_forward(cfg, params, bx, layer_idx)
        return jnp.mean((jnp.argmax(logits, axis=1) == by)
                        .astype(jnp.float32))

    n, correct = images.shape[0], 0.0
    for s in range(0, n, batch):
        bx = jnp.asarray(images[s:s + batch])
        by = jnp.asarray(labels[s:s + batch])
        correct += float(acc(params, bx, by)) * bx.shape[0]
    return correct / n
