"""Hand-rolled Adam optimizer + training loops (build-time only; no optax
in this offline image).

Hyper-parameters follow the paper: base model Adam lr 5e-3 (paper: 20 epochs
on CIFAR10); bottleneck/fine-tune Adam lr 5e-4 (paper: up to 50 epochs).
Step counts are scaled to the slim model / synthetic data so that
`make artifacts` completes in minutes on CPU.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np


def adam_init(params):
    z = {k: jnp.zeros_like(v) for k, v in params.items()}
    return {"m": z, "v": {k: jnp.zeros_like(v) for k, v in params.items()},
            "t": jnp.zeros((), jnp.int32)}


def adam_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8,
                trainable=None):
    """One Adam step. `trainable`: optional set of param names to update
    (used to freeze the backbone while training the bottleneck, Eq. 3)."""
    t = state["t"] + 1
    m, v, out = {}, {}, {}
    tf = jnp.asarray(t, jnp.float32)
    for k in params:
        g = grads[k]
        mk = b1 * state["m"][k] + (1 - b1) * g
        vk = b2 * state["v"][k] + (1 - b2) * g * g
        m[k], v[k] = mk, vk
        mhat = mk / (1 - b1 ** tf)
        vhat = vk / (1 - b2 ** tf)
        step = lr * mhat / (jnp.sqrt(vhat) + eps)
        if trainable is not None and k not in trainable:
            out[k] = params[k]
        else:
            out[k] = params[k] - step
    return out, {"m": m, "v": v, "t": t}


def make_train_step(loss_fn, lr, trainable=None):
    """Returns a jitted (params, state, batch...) -> (params, state, loss)."""

    @jax.jit
    def step(params, state, *batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, *batch)
        params2, state2 = adam_update(params, grads, state, lr,
                                      trainable=trainable)
        return params2, state2, loss

    return step


def iterate_minibatches(images, labels, batch, seed):
    """Infinite shuffled minibatch generator over numpy arrays."""
    rng = np.random.default_rng(seed)
    n = images.shape[0]
    while True:
        order = rng.permutation(n)
        for s in range(0, n - batch + 1, batch):
            idx = order[s:s + batch]
            yield images[idx], labels[idx]


def train(loss_fn, params, images, labels, steps, batch, lr, seed=0,
          trainable=None, log_every=0, tag=""):
    """Generic training loop; returns (params, [losses])."""
    step = make_train_step(loss_fn, lr, trainable=trainable)
    state = adam_init(params)
    it = iterate_minibatches(images, labels, batch, seed)
    losses = []
    for s in range(steps):
        bx, by = next(it)
        params, state, loss = step(params, state, jnp.asarray(bx),
                                   jnp.asarray(by))
        losses.append(float(loss))
        if log_every and (s + 1) % log_every == 0:
            print(f"  [{tag}] step {s + 1}/{steps} loss {float(loss):.4f}",
                  flush=True)
    return params, losses


def eval_accuracy(acc_fn, params, images, labels, batch=128):
    """Batched accuracy over a numpy test set."""
    n = images.shape[0]
    correct, total = 0.0, 0
    for s in range(0, n, batch):
        bx = jnp.asarray(images[s:s + batch])
        by = jnp.asarray(labels[s:s + batch])
        correct += float(acc_fn(params, bx, by)) * bx.shape[0]
        total += bx.shape[0]
    return correct / total
