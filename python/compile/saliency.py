"""Grad-CAM based Cumulative Saliency (CS) curve — paper Sec. III, Eqs. 1-2.

For feature layer i and input j of class c:

  Eq. 1:  alpha^c_{i,j} = spatial-pool of  d y^c / d F^{i,j}   (per channel)
  Eq. 2:  L^i_{j,c}     = ReLU( sum_z alpha_z * F_z )
  CS^i_{j,c}            = spatial mean of L^i_{j,c}
  CS^i                  = mean over all inputs j of all classes c

Note on Eq. 2 as printed: the paper writes a sum over layers k=i..I, which is
dimensionally inconsistent (feature maps of different layers have different
shapes) — the I-SPLIT paper this generalizes computes the per-layer map, and
so do we. The per-layer map *does* depend on the whole downstream network
through the gradient, which is what the k=i..I sum gestures at.

The inner reduction (weighted sum -> ReLU -> mean) is the L1 Pallas kernel
`kernels.saliency.saliency_reduce`; `cs_layer_fn` is what `aot.py` lowers to
one HLO artifact per layer so the Rust coordinator can compute the CS curve
on the request path without Python.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .kernels.saliency import saliency_reduce


def cs_layer_fn(cfg, layer_idx, use_kernel=True):
    """Returns f(params, x, y) -> CS values [B] for feature layer layer_idx.

    y is the target class per input (the paper uses the correct class).
    The gradient d y^c / d F^i is taken through the *downstream* network
    (layers layer_idx+1 .. classifier), per Eq. 1.
    """

    def fn(params, x, y):
        feat = M.forward_features(cfg, params, x, upto=layer_idx)

        def downstream_score(f):
            logits = M.forward_from(cfg, params, f, layer_idx + 1)
            onehot = jax.nn.one_hot(y, cfg.num_classes, dtype=jnp.float32)
            # sum of per-sample target logits: batch rows are independent,
            # so grad w.r.t. feat gives per-sample gradients.
            return jnp.sum(logits * onehot)

        grad = jax.grad(downstream_score)(feat)          # [B, Z, H, W]
        alpha = jnp.mean(grad, axis=(2, 3))              # Eq. 1 (GAP)
        if use_kernel:
            cs = saliency_reduce(feat, alpha)            # L1 kernel
        else:
            cam = jnp.einsum("bzhw,bz->bhw", feat, alpha)
            cs = jnp.mean(jnp.maximum(cam, 0.0), axis=(1, 2))
        # Per-layer scale normalization: raw CAM magnitude grows orders of
        # magnitude with depth (activation * gradient scale), which would
        # bury the early-layer structure the paper's Fig. 2 shows. Dividing
        # by Z * rms(F) * rms(alpha) makes CS a correlation-like quantity
        # comparable across layers (the generalization step over I-SPLIT
        # this paper claims: any signal, any layer width).
        z = feat.shape[1]
        denom = (z
                 * jnp.sqrt(jnp.mean(feat ** 2, axis=(1, 2, 3)))
                 * jnp.sqrt(jnp.mean(alpha ** 2, axis=1)) + 1e-12)
        return cs / denom

    return fn


def cs_curve(cfg, params, images, labels, batch=64, use_kernel=False,
             layers=None):
    """CS^i for every feature layer, averaged over the dataset.

    Curve is min-max normalized to [0, 1] (the paper plots a normalized
    saliency axis), making layers of different widths comparable.
    """
    layers = list(range(M.NUM_FEATURE_LAYERS)) if layers is None else layers
    n = images.shape[0]
    raw = []
    for li in layers:
        fn = jax.jit(cs_layer_fn(cfg, li, use_kernel=use_kernel))
        acc = 0.0
        for s in range(0, n, batch):
            bx = jnp.asarray(images[s:s + batch])
            by = jnp.asarray(labels[s:s + batch])
            acc += float(jnp.sum(fn(params, bx, by)))
        raw.append(acc / n)
    raw = np.asarray(raw, dtype=np.float64)
    lo, hi = raw.min(), raw.max()
    norm = (raw - lo) / (hi - lo) if hi > lo else np.zeros_like(raw)
    return norm, raw


def local_maxima(curve, min_layer=2, max_layer=None):
    """Candidate split points = indices of local maxima of the CS curve.

    Endpoints are excluded (splitting at layer 0 is LC-with-extra-steps and
    at the last layer is just RC of the classifier); plateaus take the first
    index. `min_layer` skips the earliest layers where splitting is
    pointless (head smaller than the input itself).
    """
    n = len(curve)
    max_layer = n - 2 if max_layer is None else max_layer
    out = []
    for i in range(max(1, min_layer), min(n - 1, max_layer + 1)):
        if curve[i] > curve[i - 1] and curve[i] >= curve[i + 1]:
            out.append(i)
    return out
