"""L2: VGG16-slim forward/backward in pure JAX, calling the L1 kernels.

Exact VGG16 topology (13 conv + 5 maxpool, Simonyan & Zisserman 2014) with a
configurable width multiplier. The paper trains the PyTorch VGG16 on CIFAR10;
we train the same topology at 32x32 with width 1/8 so `make artifacts` is a
few minutes on CPU. The CS-curve structure (pooling discontinuities, block
plateaus) depends on topology, not width — see DESIGN.md.

Feature layers are indexed **0-based over the 18 conv/pool layers** (ReLU is
folded into its conv). In this indexing the paper's candidate split points
are: 5 = block2_pool, 9 = block3_pool, 11 = block4_conv2, 13 = block4_pool,
15 = block5_conv2 — exactly the indices quoted in the paper's Fig. 2.

Parameters are a flat `dict[str, jnp.ndarray]`; the AOT exporter flattens
them in the deterministic order of `param_names()` so the Rust runtime can
feed them positionally.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels import matmul as pallas_matmul

# VGG16 configuration: conv output channels, 'M' = 2x2 maxpool stride 2.
VGG16_CFG = [64, 64, "M", 128, 128, "M", 256, 256, 256, "M",
             512, 512, 512, "M", 512, 512, 512, "M"]

# Keras-style layer names aligned with VGG16_CFG (and with the paper's Fig 2).
VGG16_LAYER_NAMES = [
    "block1_conv1", "block1_conv2", "block1_pool",
    "block2_conv1", "block2_conv2", "block2_pool",
    "block3_conv1", "block3_conv2", "block3_conv3", "block3_pool",
    "block4_conv1", "block4_conv2", "block4_conv3", "block4_pool",
    "block5_conv1", "block5_conv2", "block5_conv3", "block5_pool",
]

NUM_FEATURE_LAYERS = len(VGG16_CFG)  # 18


class ModelConfig:
    """Static model hyper-parameters."""

    def __init__(self, width_mult=0.125, num_classes=10, img_size=32,
                 hidden=64, use_pallas=False):
        self.width_mult = width_mult
        self.num_classes = num_classes
        self.img_size = img_size
        self.hidden = hidden          # classifier hidden width
        self.use_pallas = use_pallas

    def channels(self):
        """Per-feature-layer output channels (pool repeats its input)."""
        chans, cur = [], 3
        for c in VGG16_CFG:
            if c == "M":
                chans.append(cur)
            else:
                cur = max(int(c * self.width_mult), 4)
                chans.append(cur)
        return chans

    def conv_layers(self):
        """[(feature_layer_idx, in_ch, out_ch), ...] for the 13 convs."""
        out, cur = [], 3
        for i, c in enumerate(VGG16_CFG):
            if c == "M":
                continue
            oc = max(int(c * self.width_mult), 4)
            out.append((i, cur, oc))
            cur = oc
        return out

    def feature_shape(self, layer_idx):
        """(C, H, W) of the output of feature layer `layer_idx` (0-based)."""
        chans = self.channels()
        size = self.img_size
        for i, c in enumerate(VGG16_CFG[: layer_idx + 1]):
            if c == "M":
                size //= 2
        return (chans[layer_idx], size, size)

    def flat_feature_dim(self):
        c, h, w = self.feature_shape(NUM_FEATURE_LAYERS - 1)
        return c * h * w


def param_names(cfg):
    """Deterministic flat parameter order (the rust-side feeding order)."""
    names = []
    for i, _, _ in cfg.conv_layers():
        names += [f"conv{i}_w", f"conv{i}_b"]
    names += ["fc0_w", "fc0_b", "fc1_w", "fc1_b"]
    return names


def init_params(cfg, seed=0):
    """He-init conv + classifier parameters."""
    rng = np.random.default_rng(seed)
    params = {}
    for i, ic, oc in cfg.conv_layers():
        fan_in = ic * 9
        params[f"conv{i}_w"] = jnp.asarray(
            rng.normal(0, np.sqrt(2.0 / fan_in), (oc, ic, 3, 3)), jnp.float32)
        params[f"conv{i}_b"] = jnp.zeros((oc,), jnp.float32)
    d = cfg.flat_feature_dim()
    params["fc0_w"] = jnp.asarray(
        rng.normal(0, np.sqrt(2.0 / d), (d, cfg.hidden)), jnp.float32)
    params["fc0_b"] = jnp.zeros((cfg.hidden,), jnp.float32)
    params["fc1_w"] = jnp.asarray(
        rng.normal(0, np.sqrt(2.0 / cfg.hidden), (cfg.hidden, cfg.num_classes)),
        jnp.float32)
    params["fc1_b"] = jnp.zeros((cfg.num_classes,), jnp.float32)
    return params


def _conv2d_jnp(x, w, b):
    """3x3 same conv, NCHW."""
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))
    return y + b[None, :, None, None]


def _conv2d_pallas(x, w, b):
    """Same conv lowered as im2col x weight matmul through the L1 kernel."""
    n, c, h, wd = x.shape
    oc = w.shape[0]
    patches = jax.lax.conv_general_dilated_patches(
        x, filter_shape=(3, 3), window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NCHW", "OIHW", "NCHW"))      # [N, C*9, H, W]
    cols = patches.transpose(0, 2, 3, 1).reshape(n * h * wd, c * 9)
    wmat = w.reshape(oc, c * 9).T                        # [C*9, OC]
    y = pallas_matmul.matmul(cols, wmat)                 # L1 kernel
    y = y.reshape(n, h, wd, oc).transpose(0, 3, 1, 2)
    return y + b[None, :, None, None]


def _maxpool2(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 1, 2, 2), (1, 1, 2, 2), "VALID")


def feature_layer(cfg, params, x, layer_idx):
    """Apply feature layer `layer_idx` to input x."""
    c = VGG16_CFG[layer_idx]
    if c == "M":
        return _maxpool2(x)
    conv = _conv2d_pallas if cfg.use_pallas else _conv2d_jnp
    y = conv(x, params[f"conv{layer_idx}_w"], params[f"conv{layer_idx}_b"])
    return jax.nn.relu(y)


def forward_features(cfg, params, x, upto=None):
    """Run feature layers 0..=upto (all 18 if upto is None)."""
    last = NUM_FEATURE_LAYERS - 1 if upto is None else upto
    for i in range(last + 1):
        x = feature_layer(cfg, params, x, i)
    return x


def forward_from(cfg, params, feat, start):
    """Run feature layers start..17 then the classifier head."""
    x = feat
    for i in range(start, NUM_FEATURE_LAYERS):
        x = feature_layer(cfg, params, x, i)
    return classifier(cfg, params, x)


def classifier(cfg, params, feat):
    x = feat.reshape(feat.shape[0], -1)
    x = jax.nn.relu(x @ params["fc0_w"] + params["fc0_b"])
    return x @ params["fc1_w"] + params["fc1_b"]


def forward(cfg, params, x):
    """Full model: logits [B, num_classes]."""
    return classifier(cfg, params, forward_features(cfg, params, x))


def loss_ce(cfg, params, x, y):
    """Cross-entropy training loss (base model training)."""
    logits = forward(cfg, params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def loss_task_mse(cfg, params, x, y):
    """Paper Eq. 4: MSE between model output and one-hot ground truth."""
    logits = forward(cfg, params, x)
    onehot = jax.nn.one_hot(y, cfg.num_classes, dtype=jnp.float32)
    return jnp.mean(jnp.sum((logits - onehot) ** 2, axis=1))


def accuracy(cfg, params, x, y):
    return jnp.mean((jnp.argmax(forward(cfg, params, x), axis=1) == y)
                    .astype(jnp.float32))


# ---------------------------------------------------------------------------
# Static statistics (mirrors rust/src/model/ — cross-checked in tests)
# ---------------------------------------------------------------------------

def layer_stats(cfg):
    """[(name, out_shape(C,H,W), params, mult_adds_per_image), ...]."""
    rows = []
    size, cur = cfg.img_size, 3
    for i, c in enumerate(VGG16_CFG):
        name = VGG16_LAYER_NAMES[i]
        if c == "M":
            size //= 2
            rows.append((name, (cur, size, size), 0, 0))
        else:
            oc = max(int(c * cfg.width_mult), 4)
            p = oc * cur * 9 + oc
            ma = oc * cur * 9 * size * size
            rows.append((name, (oc, size, size), p, ma))
            cur = oc
    d = cur * size * size
    rows.append(("fc0", (cfg.hidden,), d * cfg.hidden + cfg.hidden,
                 d * cfg.hidden))
    rows.append(("fc1", (cfg.num_classes,),
                 cfg.hidden * cfg.num_classes + cfg.num_classes,
                 cfg.hidden * cfg.num_classes))
    return rows


def total_params(cfg):
    return sum(r[2] for r in layer_stats(cfg))
