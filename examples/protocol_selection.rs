//! Protocol selection (paper Sec. V-C / Fig. 4): the same RC application
//! over TCP and UDP across loss rates — TCP keeps accuracy and pays
//! latency; UDP keeps latency and pays accuracy.
//!
//!     cargo run --release --example protocol_selection [artifacts]

use std::path::Path;

use sei::coordinator::{
    self, ModelScale, QosRequirements, ScenarioConfig, ScenarioKind,
};
use sei::model::DeviceProfile;
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::runtime::{load_backend, InferenceBackend};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let engine = load_backend(Path::new(&artifacts))?;
    let test = engine.dataset("test")?;
    let qos = QosRequirements::none();

    println!("=== RC protocol selection: TCP vs UDP (1 Gb/s FD) ===\n");
    println!(
        "{:<6} {:>5} | {:>9} {:>12} | {:>9} {:>12}",
        "", "", "TCP acc", "TCP latency", "UDP acc", "UDP latency"
    );
    for loss in [0.0, 0.01, 0.03, 0.05, 0.08, 0.10] {
        let mut row = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
        for protocol in [Protocol::Tcp, Protocol::Udp] {
            let cfg = ScenarioConfig::two_tier(
                ScenarioKind::Rc,
                NetworkConfig::gigabit(protocol, loss, 99),
                DeviceProfile::edge_gpu(),
                DeviceProfile::server_gpu(),
                ModelScale::Slim,
                50_000_000,
            );
            let r = coordinator::run_scenario(&*engine, &cfg, &test, 128,
                                              &qos)?;
            match protocol {
                Protocol::Tcp => {
                    row.0 = r.accuracy;
                    row.1 = r.mean_latency_ns / 1e6;
                }
                Protocol::Udp => {
                    row.2 = r.accuracy;
                    row.3 = r.mean_latency_ns / 1e6;
                }
            }
        }
        println!(
            "{:<6} {:>4.0}% | {:>8.1}% {:>9.3} ms | {:>8.1}% {:>9.3} ms",
            "loss", loss * 100.0, row.0 * 100.0, row.1,
            row.2 * 100.0, row.3
        );
    }
    println!(
        "\nTCP: accuracy loss-independent, latency grows (retransmissions)."
    );
    println!("UDP: latency loss-independent, accuracy decays (corruption).");
    Ok(())
}
