//! Design-space sweep example: load a declarative `SweepSpec` grid from
//! JSON, evaluate every condition × placement point on the parallel sweep
//! engine, and print the accuracy-vs-latency Pareto frontier.
//!
//! cargo run --release --example sweep_grid [spec.json] [threads]
//!
//! Works hermetically on the analytic backend (no artifacts needed); with
//! the `xla` feature and built artifacts it sweeps the real model.

use std::path::Path;

use sei::coordinator::{run_sweep, SweepSpec};
use sei::runtime::load_backend_for;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // `cargo run` keeps the caller's cwd, `cargo bench`/package-relative
    // runs start in rust/ — probe both locations for the default spec.
    let spec_path = match args.first() {
        Some(p) => p.clone(),
        None => ["examples/specs/grid.json", "../examples/specs/grid.json"]
            .iter()
            .find(|p| Path::new(p).exists())
            .unwrap_or(&"examples/specs/grid.json")
            .to_string(),
    };
    let threads = match args.get(1) {
        Some(t) => t.parse()?,
        None => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    };

    let text = std::fs::read_to_string(&spec_path)?;
    let spec = SweepSpec::from_json(&text)?;
    let jobs = spec.expand()?.len();
    println!(
        "sweep '{}' from {spec_path}: {jobs} grid points on {threads} \
         thread(s)\n",
        spec.name
    );

    let t0 = std::time::Instant::now();
    let report = run_sweep(&spec, threads, &|arch| {
        load_backend_for(Path::new("artifacts"), arch)
    })?;
    print!("{}", report.render());
    println!("\nswept {jobs} points in {:.2}s", t0.elapsed().as_secs_f64());

    std::fs::create_dir_all("reports")?;
    std::fs::write("reports/sweep_grid.json", report.to_json().to_string())?;
    report.to_csv().write(Path::new("reports/sweep_grid.csv"))?;
    println!("wrote reports/sweep_grid.json, reports/sweep_grid.csv");
    Ok(())
}
