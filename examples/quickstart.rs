//! Quickstart: load a backend, print the model card, compare LC / RC /
//! SC on a short workload, and ask the framework for a suggestion.
//!
//! Runs hermetically on the analytic backend — no artifacts or XLA needed:
//!     cargo run --release --example quickstart
//! With the `xla` feature and built artifacts it serves the real model.

use std::path::Path;

use sei::coordinator::{
    self, CsCurve, ModelScale, QosRequirements, ScenarioConfig, ScenarioKind,
};
use sei::model::DeviceProfile;
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::runtime::{load_backend, InferenceBackend};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let engine = load_backend(Path::new(&artifacts))?;
    let m = &engine.manifest().model;
    println!("=== Split-Et-Impera quickstart ===");
    println!(
        "model: {} ({} params), trained test accuracy {:.1}%",
        m.arch,
        m.total_params,
        m.base_test_accuracy * 100.0
    );
    println!("backend: {} ({})\n", engine.name(), engine.platform());

    // 1. Saliency-based split-point candidates (paper Fig. 1, step i).
    let curve = CsCurve::from_manifest(engine.manifest());
    let candidates = curve.candidates(2);
    println!("CS candidate split points: {candidates:?}");
    for &c in &candidates {
        if let Some(row) = engine.manifest().split_eval_for(c) {
            println!(
                "  L{c:<2} {:<14} split accuracy {:.1}%, latent {} B/frame",
                row.layer_name,
                row.accuracy * 100.0,
                row.latent_bytes_per_image
            );
        }
    }

    // 2. Simulate LC, RC and the best-available SC on a Gigabit TCP channel
    //    with 2% loss (paper Fig. 1, step ii).
    let qos = QosRequirements::ice_lab();
    let test = engine.dataset("test")?;
    let split = *candidates.last().unwrap_or(&13);
    println!("\nscenario comparison (TCP, 1 Gb/s, 2% loss, QoS {}):",
             qos.describe());
    for kind in [ScenarioKind::Lc, ScenarioKind::Rc,
                 ScenarioKind::Sc { split }] {
        let cfg = ScenarioConfig::two_tier(
            kind.clone(),
            NetworkConfig::gigabit(Protocol::Tcp, 0.02, 7),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            ModelScale::Slim,
            50_000_000,
        );
        let r = coordinator::run_scenario(&*engine, &cfg, &test, 96,
                                          &qos)?;
        println!(
            "  {:<8} accuracy {:>5.1}%  mean latency {:>8.3} ms  {}",
            kind.to_string(),
            r.accuracy * 100.0,
            r.mean_latency_ns / 1e6,
            match r.qos_satisfied {
                Some(true) => "QoS ok",
                Some(false) => "QoS violated",
                None => "",
            }
        );
    }

    // 3. Ask the suggestion engine (paper Fig. 1, step iii).
    let suggestions = coordinator::suggest(
        &*engine,
        &NetworkConfig::gigabit(Protocol::Tcp, 0.02, 7),
        &[DeviceProfile::edge_gpu(), DeviceProfile::server_gpu()],
        &qos,
        &test,
        96,
        2,
    )?;
    if let Some(best) = coordinator::best(&suggestions) {
        println!(
            "\nframework suggestion: {} (accuracy {:.1}%, {:.2} ms)",
            best.rank.kind,
            best.report.accuracy * 100.0,
            best.report.mean_latency_ns / 1e6
        );
    }
    Ok(())
}
