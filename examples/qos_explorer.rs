//! QoS design-space explorer: sweep channel presets x protocols x split
//! points and print which configurations satisfy a target QoS — the
//! "three-dimensional design space" of the paper's introduction, explored
//! by rapid simulation instead of try-and-test deployment.
//!
//!     cargo run --release --example qos_explorer [artifacts]

use std::path::Path;

use sei::coordinator::{
    self, ModelScale, QosRequirements, ScenarioConfig, ScenarioKind,
};
use sei::model::DeviceProfile;
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::runtime::{load_backend, InferenceBackend};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let engine = load_backend(Path::new(&artifacts))?;
    let test = engine.dataset("test")?;
    let qos = QosRequirements::with_fps(20.0)?.and_accuracy(0.85);
    println!("=== QoS explorer: {} ===\n", qos.describe());

    let channels: [(&str, fn(Protocol, f64, u64) -> NetworkConfig); 3] = [
        ("gigabit", NetworkConfig::gigabit),
        ("fast-ethernet", NetworkConfig::fast_ethernet),
        ("wifi", NetworkConfig::wifi),
    ];
    let mut kinds = vec![ScenarioKind::Lc, ScenarioKind::Rc];
    for s in engine.manifest().available_splits() {
        kinds.push(ScenarioKind::Sc { split: s });
    }

    println!(
        "{:<14} {:<5} {:<8} {:>9} {:>12} {:>8}",
        "channel", "proto", "config", "accuracy", "mean lat", "QoS"
    );
    let loss = 0.02;
    for (cname, make) in channels {
        for protocol in [Protocol::Tcp, Protocol::Udp] {
            for kind in &kinds {
                let cfg = ScenarioConfig::two_tier(
                    kind.clone(),
                    make(protocol, loss, 7),
                    DeviceProfile::edge_gpu(),
                    DeviceProfile::server_gpu(),
                    ModelScale::Slim,
                    50_000_000,
                );
                let r = coordinator::run_scenario(&*engine, &cfg, &test,
                                                  64, &qos)?;
                let ok = qos
                    .satisfied_by(r.deadline_hit_rate, r.accuracy);
                println!(
                    "{:<14} {:<5} {:<8} {:>8.1}% {:>9.3} ms {:>8}",
                    cname,
                    protocol.to_string(),
                    kind.to_string(),
                    r.accuracy * 100.0,
                    r.mean_latency_ns / 1e6,
                    if ok { "ok" } else { "-" }
                );
            }
        }
    }
    Ok(())
}
