//! End-to-end validation driver (DESIGN.md): the ICE-Lab conveyor-belt
//! classification application from the paper's evaluation (Sec. V).
//!
//! Streams the ICE-Lab image stream at 20 FPS through the full split-
//! computing pipeline — head inference on the (simulated) edge device,
//! latent transfer over the simulated TCP channel, tail inference on the
//! server — with actual backend execution of both model halves (PJRT
//! under the `xla` feature, the analytic reference otherwise), and reports
//! accuracy, latency and the QoS verdict for several loss rates.
//!
//!     cargo run --release --example ice_lab_conveyor [artifacts] [frames]

use std::path::Path;

use sei::coordinator::{
    self, ModelScale, QosRequirements, ScenarioConfig, ScenarioKind,
};
use sei::model::DeviceProfile;
use sei::netsim::transfer::{NetworkConfig, Protocol};
use sei::runtime::{load_backend, InferenceBackend};

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let frames: usize = std::env::args()
        .nth(2)
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(480);
    let engine = load_backend(Path::new(&artifacts))?;
    let ice = engine.dataset("ice")?;
    let qos = QosRequirements::ice_lab(); // 0.05 s / 20 FPS conveyor

    // Pick the deepest exported split (smallest latent on the wire).
    let splits = engine.manifest().available_splits();
    let split = *splits.last().expect("no split artifacts");
    println!("=== ICE-Lab conveyor, split computing at L{split} ===");
    println!(
        "workload: {} frames @ 20 FPS from the ICE stream ({} images)\n",
        frames,
        ice.len()
    );

    for loss in [0.0, 0.02, 0.05] {
        let cfg = ScenarioConfig::two_tier(
            ScenarioKind::Sc { split },
            NetworkConfig::gigabit(Protocol::Tcp, loss, 1234),
            DeviceProfile::edge_gpu(),
            DeviceProfile::server_gpu(),
            ModelScale::Slim,
            50_000_000,
        );
        let report = coordinator::serve(&*engine, &cfg, &ice, frames,
                                        &qos)?;
        println!("--- loss rate {:.0}% ---", loss * 100.0);
        print!("{}", report.render(&qos));
        println!();
    }
    Ok(())
}
