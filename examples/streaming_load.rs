//! Streaming load curve: drive the closed-loop serving simulator past its
//! saturation point and watch queueing appear.
//!
//! Sweeps the new `clients` × `offered_fps` axes of the design-space
//! sweep engine over a paper-scale SC@L11 deployment (VGG16 @ 224×224,
//! ~803 kB latent per frame, TCP over 1 Gb/s) and prints the classic
//! load-latency curve: below the bottleneck capacity, latency is flat and
//! throughput tracks the offered rate; past it, throughput plateaus at
//! the bottleneck while mean/p99 latency and queue depth take off — the
//! behaviour the old open-loop engine could not show at all.
//!
//!     cargo run --release --example streaming_load [threads]

use std::path::Path;

use sei::coordinator::{
    run_sweep, ModelScale, ScenarioKind, SweepMode, SweepSpec,
};
use sei::netsim::transfer::Protocol;
use sei::runtime::load_backend_for;

fn main() -> anyhow::Result<()> {
    let threads = match std::env::args().nth(1) {
        Some(t) => t.parse()?,
        None => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
    };

    let mut spec = SweepSpec::new("streaming_load");
    spec.mode = SweepMode::LatencyOnly;
    spec.scenarios = vec![ScenarioKind::Sc { split: 11 }];
    spec.protocols = vec![Protocol::Tcp];
    spec.loss_rates = vec![0.0];
    spec.scales = vec![ModelScale::Full];
    spec.clients = vec![1, 4];
    spec.offered_fps = vec![10.0, 20.0, 40.0, 80.0, 160.0];
    spec.frames = 120;
    spec.max_latency_ms = 50.0; // the ICE-Lab 20 FPS deadline
    spec.seed = 2024;

    let n_rates = spec.offered_fps.len();
    println!(
        "=== streaming load curve: SC@L11, VGG16 volumetrics, TCP 1 Gb/s ===",
    );
    println!(
        "edge head ≈ 11 GMAC (~11 ms/frame/client), L11 latent ≈ 803 kB \
         (~6.5 ms on the shared uplink)\n{} grid points x {} frames/client \
         on {threads} thread(s)\n",
        spec.expand()?.len(),
        spec.frames
    );

    let report = run_sweep(&spec, threads, &|arch| {
        load_backend_for(Path::new("artifacts"), arch)
    })?;

    for (ci, &clients) in spec.clients.iter().enumerate() {
        println!(
            "-- {clients} client(s), per-client offered rate sweep --"
        );
        println!(
            "{:>12} {:>12} {:>12} {:>12} {:>12} {:>10} {:>10}",
            "offered FPS", "achieved", "mean lat", "p99 lat",
            "queue depth", "hit-rate", "verdict"
        );
        for (ri, _) in spec.offered_fps.iter().enumerate() {
            let p = &report.points[ci * n_rates + ri];
            println!(
                "{:>12.0} {:>12.1} {:>9.2} ms {:>9.2} ms {:>12.1} {:>9.1}% \
                 {:>10}",
                p.offered_fps.unwrap_or(0.0) * p.clients as f64,
                p.throughput_fps,
                p.mean_latency_ns / 1e6,
                p.p99_latency_ns as f64 / 1e6,
                p.mean_queue_depth,
                p.deadline_hit_rate.unwrap_or(0.0) * 100.0,
                match p.satisfies {
                    Some(true) => "ok",
                    Some(false) => "violated",
                    None => "—",
                },
            );
        }
        let last = &report.points[ci * n_rates + n_rates - 1];
        let prev = &report.points[ci * n_rates + n_rates - 2];
        println!(
            "   -> saturation: offered {:.0} vs {:.0} FPS both achieve \
             ~{:.0} FPS (bottleneck), latency x{:.1}\n",
            prev.offered_fps.unwrap_or(0.0) * prev.clients as f64,
            last.offered_fps.unwrap_or(0.0) * last.clients as f64,
            last.throughput_fps,
            last.mean_latency_ns
                / report.points[ci * n_rates].mean_latency_ns.max(1.0),
        );
    }
    println!(
        "note: with 1 client the per-client edge device (~88 FPS) is the \
         bottleneck; with 4 clients the shared channel saturates first — \
         exactly the placement trade-off the paper's framework explores."
    );
    Ok(())
}
